"""Reproduce the paper's baseline comparison (Fig 3) and the composition
result (Table 5): the MELINOE fine-tuned checkpoint improves *other*
offloading systems too.

    PYTHONPATH=src python examples/compose_baselines.py
"""
import numpy as np

from repro.configs import get_config
from repro.core.baselines import BASELINES, make_engine
from repro.core.lora import lora_scale
from repro.data.synthetic import ClusterLM, SyntheticConfig
from repro.training.trainer import melinoe_finetune, merge_lora, pretrain


def main():
    cfg = get_config("granite-moe-1b-a400m-smoke")
    lm = ClusterLM(SyntheticConfig(vocab=cfg.vocab, seq_len=48, n_clusters=4))
    base = pretrain(cfg, lm.batches(6, seed=1), steps=30, log_every=15)
    ft = melinoe_finetune(cfg, base.params, lm.batches(6, seed=2), steps=20,
                          log_every=10)
    merged = merge_lora(cfg, ft.params, ft.lora, lora_scale(cfg.melinoe))

    rng = np.random.default_rng(0)
    prompts = np.stack([lm.sample_sequence(rng, cluster=1)[0][:24] for _ in range(2)])
    C = cfg.melinoe_cache_capacity()

    print(f"\n{'policy':20s} {'checkpoint':10s} {'transfers':>9s} {'tok/s':>8s}")
    for name, spec in sorted(BASELINES.items()):
        for pname, params in [("base", base.params), ("finetuned", merged)]:
            eng = make_engine(cfg, params, spec, capacity=C)
            res = eng.generate(prompts, max_new_tokens=16)
            print(f"{name:20s} {pname:10s} {res['metrics'].transfers:9d} "
                  f"{res['throughput_tok_s']:8.1f}")


if __name__ == "__main__":
    main()
