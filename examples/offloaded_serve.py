"""Serve a small MoE with batched requests under a tight expert-cache
budget, with the full MELINOE post-deployment stack: activation
predictor -> prefetch -> gamma-cache offloaded decoding (paper Sec 3.2).

    PYTHONPATH=src python examples/offloaded_serve.py [--ckpt checkpoints/olmoe-mini_melinoe.ckpt]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.offload_engine import HardwareProfile, OffloadedMoEEngine
from repro.core.predictor import (
    PromptEmbedder,
    init_predictor,
    predict_scores,
    train_predictor,
)
from repro.data.synthetic import ClusterLM, SyntheticConfig
from repro.inference.engine import routing_trace
from repro.models.model import init_params
from repro.training.checkpoint import load_checkpoint
from repro.training.trainer import melinoe_finetune, merge_lora, pretrain
from repro.core.lora import lora_scale


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmoe-mini")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--capacity", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    lm = ClusterLM(SyntheticConfig(vocab=cfg.vocab, seq_len=32))
    if args.ckpt:
        like = jax.eval_shape(lambda: init_params(jax.random.key(0), cfg, jnp.float32))
        params, _, _ = load_checkpoint(args.ckpt, like)
        print(f"loaded {args.ckpt}")
    else:
        print("no --ckpt: quick-training a demo checkpoint (base 30 + ft 20 steps)")
        base = pretrain(cfg, lm.batches(6, seed=1), steps=30, log_every=15)
        ft = melinoe_finetune(cfg, base.params, lm.batches(6, seed=2), steps=20,
                              log_every=10)
        params = merge_lora(cfg, ft.params, ft.lora, lora_scale(cfg.melinoe))

    C = args.capacity or cfg.melinoe_cache_capacity()
    hw = HardwareProfile()

    # --- train the activation predictor on routing traces (Sec 3.1.2) ---
    emb = PromptEmbedder(cfg.vocab)
    rng = np.random.default_rng(0)
    train_prompts = np.stack(
        [lm.sample_sequence(rng)[0][:24] for _ in range(24)]
    ).astype(np.int32)
    _, probs = routing_trace(cfg, params, train_prompts, max_new=12)
    targets = jnp.asarray(probs.mean(axis=2))
    embs = jnp.stack([emb(jnp.asarray(p)) for p in train_prompts])
    pp = init_predictor(jax.random.key(1), targets.shape[1], targets.shape[2])
    pp, hist = train_predictor(pp, embs, targets, epochs=10)
    print(f"predictor KL: {hist[0]:.4f} -> {hist[-1]:.4f}")

    # --- serve a batch of requests ---
    requests = np.stack(
        [lm.sample_sequence(rng, cluster=2)[0][:24] for _ in range(args.batch)]
    ).astype(np.int32)
    engine = OffloadedMoEEngine(cfg, params, capacity=C, policy="gamma", hw=hw)
    # batched prefetch pools predictor scores across the batch (paper Fig 5)
    scores = predict_scores(pp, emb(jnp.asarray(requests)).mean(0))
    engine.prefetch(scores)

    res = engine.generate(requests, max_new_tokens=args.max_new)
    m = res["metrics"]
    print(f"\nserved batch={args.batch}, {args.max_new} tokens each, cache C={C}")
    print(f"prefetch transfers : {m.prefetch_transfers}")
    print(f"demand transfers   : {m.transfers} ({res['transfers_per_layer']:.1f}/layer)")
    print(f"cache hit rate     : {res['cache_stats'].hit_rate:.3f}")
    print(f"modeled throughput : {res['throughput_tok_s']:.1f} tok/s ({hw.name}, Eq. 3)")


if __name__ == "__main__":
    main()
