"""Quickstart: the MELINOE mechanism in ~60 lines.

Fine-tunes a tiny MoE with the cache-simulation + rank-matching losses
and shows the expert-transfer reduction under an offloaded cache.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import get_config
from repro.core.lora import lora_scale
from repro.core.offload_engine import OffloadedMoEEngine
from repro.data.synthetic import ClusterLM, SyntheticConfig, eval_batches
from repro.training.trainer import eval_nll, melinoe_finetune, merge_lora, pretrain

import numpy as np


def make_demo_config():
    """2-layer granite-moe reduction with 8 experts top-2 (C = 2): small
    enough for a CPU demo, enough experts for routing to concentrate."""
    import dataclasses

    from repro.configs.base import MoESpec

    cfg = get_config("granite-moe-1b-a400m-smoke")
    bd = {
        n: (dataclasses.replace(b, moe=MoESpec(num_experts=8, top_k=2, d_ff=b.moe.d_ff,
                                               capacity_factor=2.0))
            if b.moe is not None else b)
        for n, b in cfg.block_defs.items()
    }
    mel = dataclasses.replace(cfg.melinoe, cache_capacity=2)
    return dataclasses.replace(cfg, block_defs=bd, melinoe=mel,
                               name=cfg.name + "-demo")


def main():
    cfg = make_demo_config()
    print(f"arch: {cfg.name} ({cfg.n_layers} layers, {cfg.moe_spec.num_experts} experts, "
          f"top-{cfg.moe_spec.top_k}, melinoe C={cfg.melinoe_cache_capacity()})")

    # 1) base model: standard LM pretraining on the cluster corpus
    lm = ClusterLM(SyntheticConfig(vocab=cfg.vocab, seq_len=48, n_clusters=4))
    base = pretrain(cfg, lm.batches(6, seed=1), steps=30, log_every=10)

    # 2) pre-deployment stage: fine-tune with L = L_nll + l_cs*L_cs + l_rm*L_rm
    #    (router + expert gate full update, LoRA on expert up/down)
    ft = melinoe_finetune(cfg, base.params, lm.batches(6, seed=2), steps=24, log_every=6)
    merged = merge_lora(cfg, ft.params, ft.lora, lora_scale(cfg.melinoe))
    print(f"\ncache-sim loss: {ft.history[0]['cs_loss']:.3f} -> "
          f"{ft.history[-1]['cs_loss']:.3f}")

    # 3) post-deployment: offloaded inference with a C-expert cache
    rng = np.random.default_rng(0)
    prompts = np.stack([lm.sample_sequence(rng, cluster=1)[0][:24] for _ in range(2)])
    C = cfg.melinoe_cache_capacity()
    for name, params in [("base", base.params), ("melinoe", merged)]:
        eng = OffloadedMoEEngine(cfg, params, capacity=C, policy="gamma")
        res = eng.generate(prompts, max_new_tokens=16)
        print(f"{name:8s}: transfers={res['metrics'].transfers:4d} "
              f"({res['transfers_per_layer']:.1f}/layer)  "
              f"modeled throughput={res['throughput_tok_s']:.1f} tok/s")

    # 4) quality check (paper Table 2: fine-tuning preserves quality)
    ev = eval_batches(lm, 2, 6)
    print(f"\nheld-out NLL  base={eval_nll(cfg, base.params, ev):.4f}  "
          f"melinoe={eval_nll(cfg, merged, ev):.4f}")


if __name__ == "__main__":
    main()
