"""End-to-end driver: train a ~100M-parameter MoE (olmoe-mini) for a few
hundred steps, then run the MELINOE pre-deployment stage.

    PYTHONPATH=src python examples/train_melinoe.py --steps 200 --ft-steps 100

Checkpoints land in checkpoints/; pass --quick for a fast smoke run.
"""
import argparse
import json
from pathlib import Path

from repro.configs import get_config
from repro.core.lora import lora_scale
from repro.data.synthetic import ClusterLM, SyntheticConfig, eval_batches
from repro.training.checkpoint import save_checkpoint
from repro.training.optim import OptConfig
from repro.training.trainer import eval_nll, melinoe_finetune, merge_lora, pretrain


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmoe-mini")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ft-steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="checkpoints")
    args = ap.parse_args()
    if args.quick:
        args.steps, args.ft_steps = 20, 10

    cfg = get_config(args.arch)
    n_params = cfg.param_counts()["total"]
    print(f"{cfg.name}: {n_params/1e6:.1f}M params, {cfg.n_layers} layers, "
          f"{cfg.moe_spec.num_experts} experts")

    lm = ClusterLM(SyntheticConfig(vocab=cfg.vocab, seq_len=args.seq))
    res = pretrain(
        cfg, lm.batches(args.batch, seed=1), steps=args.steps,
        opt_cfg=OptConfig(peak_lr=3e-3, total_steps=args.steps, weight_decay=0.01),
        log_every=max(args.steps // 10, 1),
    )
    ft = melinoe_finetune(cfg, res.params, lm.batches(args.batch, seed=2),
                          steps=args.ft_steps, log_every=max(args.ft_steps // 10, 1))
    merged = merge_lora(cfg, ft.params, ft.lora, lora_scale(cfg.melinoe))

    out = Path(args.out)
    save_checkpoint(out / f"{cfg.name}_base.ckpt", res.params)
    save_checkpoint(out / f"{cfg.name}_melinoe.ckpt", merged)
    (out / f"{cfg.name}_history.json").write_text(
        json.dumps({"pretrain": res.history, "finetune": ft.history}, indent=1)
    )
    ev = eval_batches(lm, 2, args.batch)
    print(f"\nheld-out NLL: base={eval_nll(cfg, res.params, ev):.4f} "
          f"melinoe={eval_nll(cfg, merged, ev):.4f}")
    print(f"checkpoints written to {out}/")


if __name__ == "__main__":
    main()
