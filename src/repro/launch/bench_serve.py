"""Continuous-batching serving launcher.

    # fits-in-memory path: continuous batching over the jitted decode step
    PYTHONPATH=src python -m repro.launch.bench_serve --arch olmoe-mini \
        --n-requests 16 --slots 4 --scheduler fcfs

    # offloaded path: scheduler-driven prefetch between waves (Sec 3.2)
    PYTHONPATH=src python -m repro.launch.bench_serve --arch olmoe-mini \
        --offloaded --capacity 8 --scheduler expert-affinity

Synthesizes a Poisson/bursty workload over the ClusterLM prompt
distribution, serves it through the chosen scheduler, and prints the
ServerMetrics summary (throughput, latency percentiles, queue depth,
slot occupancy, and — offloaded — transfers + cache hit rate).
"""
from __future__ import annotations

import argparse
import json
import os
import signal

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..data.synthetic import ClusterLM, SyntheticConfig
from ..faults import InjectedCrash, get_fault_plan, install_fault_plan
from ..models.model import init_params
from ..obs import REGISTRY, enable_tracing, get_tracer, reconcile
from ..serving import (
    ContinuousBatchingServer,
    OffloadedWaveServer,
    RequestQueue,
    TrafficConfig,
    get_scheduler,
    prefill_expert_scores,
    synthesize_workload,
)
from ..training.checkpoint import load_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmoe-mini")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--scheduler", default="fcfs",
                    choices=["fcfs", "sjf", "expert-affinity"])
    ap.add_argument("--offloaded", action="store_true",
                    help="serve through the offloaded expert cache (Sec 3.2)")
    ap.add_argument("--overlap", action="store_true",
                    help="advance the offloaded clock by the overlapped "
                         "Eq.-3 model (layer l compute hides layer l+1 "
                         "fetches); both clocks are reported either way")
    ap.add_argument("--engine-impl", default="slab", choices=["slab", "dict"],
                    help="offloaded engine implementation (slab = grouped "
                         "jitted hot path; dict = legacy per-expert loop)")
    ap.add_argument("--capacity", type=int, default=0, help="0 => E/4 (offloaded)")
    ap.add_argument("--slots", type=int, default=4,
                    help="concurrent KV slots / wave size")
    ap.add_argument("--n-requests", type=int, default=16)
    ap.add_argument("--arrival", default="poisson",
                    choices=["poisson", "bursty", "all_at_once"])
    ap.add_argument("--rate", type=float, default=4.0, help="requests / second")
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--faults", default=None, metavar="SPEC",
                    help="install a deterministic fault plan, e.g. "
                         "'fail=0.1,spike=0.05:2e-3,storm=0.02:0.5,seed=7' "
                         "(same grammar as REPRO_FAULTS)")
    ap.add_argument("--slo", type=float, default=None,
                    help="per-request SLO in virtual seconds after arrival "
                         "(default: best effort, never shed)")
    ap.add_argument("--quality", type=float, default=1.0,
                    help="little-expert quality dial: fraction of cache "
                         "misses served by the big expert (needs --little)")
    ap.add_argument("--little", action="store_true",
                    help="build the always-resident low-rank little-expert "
                         "bank (degraded mode on fetch failure / deadline "
                         "pressure; offloaded path only)")
    ap.add_argument("--little-rank", type=int, default=8)
    ap.add_argument("--max-backlog", type=int, default=None,
                    help="bound the pending queue; the latest arrivals "
                         "beyond it are shed (admission control)")
    ap.add_argument("--trace", default=None, metavar="DIR",
                    help="enable structured tracing; write trace.json "
                         "(Perfetto), trace.jsonl, metrics.json/.prom and "
                         "— offloaded — the Eq.-3 reconciliation report "
                         "into DIR")
    ap.add_argument("--journal", default=None, metavar="DIR",
                    help="write-ahead request journal + checkpoints into "
                         "DIR (default: $REPRO_JOURNAL); enables crash "
                         "recovery via --resume")
    ap.add_argument("--checkpoint-every", type=int, default=8,
                    help="checkpoint + rotate the journal every N decode "
                         "steps (continuous) / waves (offloaded); needs "
                         "--journal")
    ap.add_argument("--audit-every", type=int, default=0,
                    help="run the invariant-audit watchdog every N steps/"
                         "waves (0 = only after a restore)")
    ap.add_argument("--resume", action="store_true",
                    help="recover from the journal dir and continue the "
                         "interrupted run (token-identical under greedy)")
    ap.add_argument("--cold-restore", action="store_true",
                    help="with --resume on the offloaded path: skip the "
                         "warm slab revival (restore policy scores only "
                         "and pay the demand misses again)")
    ap.add_argument("--out-results", default=None, metavar="PATH",
                    help="write per-request tokens + summary JSON (use to "
                         "diff a crashed+resumed run against an "
                         "uninterrupted one)")
    args = ap.parse_args()

    if args.trace:
        enable_tracing()
    if args.faults:
        install_fault_plan(args.faults)

    cfg = get_config(args.arch)
    if args.ckpt:
        like = jax.eval_shape(lambda: init_params(jax.random.key(0), cfg, jnp.float32))
        params, _, meta = load_checkpoint(args.ckpt, like)
        print(f"loaded {args.ckpt} ({meta})")
    else:
        params = init_params(jax.random.key(0), cfg, jnp.float32)
        print("using randomly initialized weights (demo mode)")

    # -- crash recovery: journal + optional restore ---------------------
    from ..recovery import RequestJournal, journal_dir_from_env, recover

    jdir = args.journal or journal_dir_from_env()
    state = None
    if args.resume:
        assert jdir, "--resume needs --journal DIR (or $REPRO_JOURNAL)"
        state = recover(jdir)
        assert state is not None, f"nothing to recover in {jdir}"
        want = "wave" if args.offloaded else "continuous"
        assert state.kind == want, (
            f"journal was written by a {state.kind!r} server; rerun with "
            f"the matching path (expected {want!r})")
        print(f"resuming from {jdir}: step={state.step} now={state.now:.3f}s "
              f"pending={len(state.pending)} finished={len(state.results)}")

    if state is not None:
        requests = state.pending  # expert scores ride in the records
        queue = state.build_queue(args.max_backlog)
    else:
        lm = ClusterLM(SyntheticConfig(vocab=cfg.vocab,
                                       seq_len=args.prompt_len * 2,
                                       seed=args.seed + 3))
        tcfg = TrafficConfig(
            n_requests=args.n_requests, arrival=args.arrival, rate=args.rate,
            prompt_len=(max(args.prompt_len // 2, 1), args.prompt_len),
            max_new_tokens=(max(args.max_new // 2, 1), args.max_new),
            temperature=args.temperature, seed=args.seed,
            slo=args.slo, quality=args.quality,
        )
        requests = synthesize_workload(lm, tcfg)
        # the burst fault compresses arrival gaps in place (overload)
        get_fault_plan().compress_arrivals(requests)
        queue = RequestQueue(requests, max_pending=args.max_backlog)

    if args.offloaded:
        assert cfg.has_router, "offloaded serving applies to MoE architectures"
        if args.temperature > 0:
            print("note: the offloaded engine decodes greedily; "
                  "--temperature is ignored on this path")
        capacity = args.capacity or cfg.melinoe_cache_capacity()
        if state is None:
            prefill_expert_scores(cfg, params, requests)  # oracle profiles
        kw = {"top_c": capacity} if args.scheduler == "expert-affinity" else {}
        srv = OffloadedWaveServer(
            cfg, params, capacity=capacity,
            scheduler=get_scheduler(args.scheduler, **kw), wave_size=args.slots,
            overlap=args.overlap, engine_impl=args.engine_impl,
            little_experts=args.little, little_rank=args.little_rank,
            seed=state.seed if state else args.seed,
        )
        if state is not None and state.engine is not None:
            srv.engine.metrics.load_state(state.engine["metrics"])
            rev = srv.engine.revive(state.engine["cache"],
                                    warm=not args.cold_restore)
            print(f"{'warm' if not args.cold_restore else 'cold'} revival: "
                  f"{rev['loaded']} experts, {rev['bytes']} bytes")
    else:
        srv = ContinuousBatchingServer(
            cfg, params, n_slots=args.slots,
            max_len=args.prompt_len + args.max_new + 1,
            scheduler=get_scheduler(args.scheduler),
            seed=state.seed if state else args.seed,
        )

    jr = RequestJournal(jdir, seen=state.seen_rids if state else None) \
        if jdir else None
    # graceful drain on SIGTERM: stop admission, finish in-flight, and
    # (journaled) anchor a final checkpoint instead of dying mid-step —
    # what a fleet supervisor or k8s preemption sends before SIGKILL
    drain_flag = {"drain": False}
    prev_term = signal.signal(
        signal.SIGTERM, lambda *_: drain_flag.__setitem__("drain", True))
    try:
        results, mt = srv.run(
            queue, state.metrics if state else None,
            journal=jr,
            checkpoint_every=args.checkpoint_every if jr else None,
            audit_every=args.audit_every or None,
            resume=state,
            should_drain=lambda: drain_flag["drain"],
        )
    except InjectedCrash as e:
        # deliberate fault-injection exit: the journal holds everything
        # needed for --resume, so this is a success for the harness
        print(f"CRASHED (injected): {e}")
        print(f"journal is recoverable at {jdir}" if jdir else
              "no journal configured; run is lost")
        return
    finally:
        if jr is not None:
            jr.close()
        signal.signal(signal.SIGTERM, prev_term)
    if getattr(srv, "drained", False):
        print(f"DRAINED on SIGTERM: {len(results)} finished, "
              f"{len(queue)} pending left "
              + (f"checkpointed in {jdir}" if jdir else "(no journal — lost)"))
    for r in results[: min(4, len(results))]:
        print(f"  rid={r.rid} {len(r.tokens)} toks ({r.finish_reason}) "
              f"latency={r.latency:.4f}s tokens={r.tokens[:8].tolist()}...")
    print(json.dumps(mt.summary(), indent=2))

    if args.out_results:
        payload = {
            "results": [{"rid": r.rid, "tokens": [int(t) for t in r.tokens],
                         "finish_reason": r.finish_reason} for r in results],
            "summary": mt.summary(),
        }
        with open(args.out_results, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"results: {args.out_results}")

    if args.trace:
        _export_trace(args.trace, srv, mt, offloaded=args.offloaded)


def _export_trace(outdir: str, srv, mt, *, offloaded: bool) -> None:
    """Dump the run's spans/metrics and (offloaded) the per-layer
    reconciliation of the Eq.-3 modeled clock against measured spans."""
    os.makedirs(outdir, exist_ok=True)
    tracer = get_tracer()
    trace_path = os.path.join(outdir, "trace.json")
    tracer.export_chrome_trace(trace_path, process_name="bench_serve")
    tracer.export_jsonl(os.path.join(outdir, "trace.jsonl"))

    mt.publish()
    get_fault_plan().publish()
    if offloaded:
        srv.engine.metrics.publish()
        srv.engine.cache.publish()
    with open(os.path.join(outdir, "metrics.json"), "w") as f:
        f.write(REGISTRY.to_json(indent=2))
    with open(os.path.join(outdir, "metrics.prom"), "w") as f:
        f.write(REGISTRY.to_prometheus_text())
    print(f"trace: {trace_path} ({len(tracer.spans())} spans)")

    if offloaded:
        report = reconcile(tracer.spans(), srv.engine.metrics, srv.engine.hw)
        with open(os.path.join(outdir, "reconcile.json"), "w") as f:
            json.dump(report.to_json(), f, indent=2)
        print(report.format_table())


if __name__ == "__main__":
    main()
