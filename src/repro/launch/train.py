"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch olmoe-mini --steps 200

On the CPU container this drives the reduced configs; on a real cluster
the same entrypoint runs under the production mesh (--mesh single|multi)
with pjit sharding from distributed/sharding.py.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..data.synthetic import ClusterLM, SyntheticConfig
from ..models.runtime import Runtime
from ..training.checkpoint import save_checkpoint
from ..training.optim import OptConfig
from ..training.trainer import melinoe_finetune, pretrain


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmoe-mini")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--mode", choices=["pretrain", "finetune", "both"], default="both")
    ap.add_argument("--ft-steps", type=int, default=100)
    ap.add_argument("--out", default="checkpoints")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    rt = Runtime()
    lm = ClusterLM(SyntheticConfig(vocab=cfg.vocab, seq_len=args.seq, seed=args.seed))
    out = Path(args.out)

    res = None
    if args.mode in ("pretrain", "both"):
        res = pretrain(
            cfg, lm.batches(args.batch, seed=args.seed + 1), steps=args.steps,
            opt_cfg=OptConfig(peak_lr=args.lr, total_steps=args.steps, weight_decay=0.01),
            rt=rt, seed=args.seed,
        )
        save_checkpoint(out / f"{cfg.name}_base.ckpt", res.params, step=args.steps,
                        metadata={"arch": cfg.name, "stage": "pretrain"})
        (out / f"{cfg.name}_base_history.json").write_text(json.dumps(res.history))

    if args.mode in ("finetune", "both") and cfg.has_router:
        assert res is not None, "finetune mode requires --mode both here"
        ft = melinoe_finetune(
            cfg, res.params, lm.batches(args.batch, seed=args.seed + 2),
            steps=args.ft_steps, rt=rt, seed=args.seed,
        )
        save_checkpoint(out / f"{cfg.name}_melinoe.ckpt", (ft.params, ft.lora),
                        step=args.ft_steps, metadata={"arch": cfg.name, "stage": "melinoe"})
        (out / f"{cfg.name}_melinoe_history.json").write_text(json.dumps(ft.history))
    print("done")


if __name__ == "__main__":
    main()
