"""ShapeDtypeStruct stand-ins for every model input — the dry-run lowers
against these (weak-type-correct, shardable, no device allocation)."""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import SHAPES, ModelConfig, ShapeSpec
from ..models.model import init_cache

SDS = jax.ShapeDtypeStruct


def decode_window_override(cfg: ModelConfig, shape: ShapeSpec) -> Optional[int]:
    """long_500k on (semi-)dense archs runs the sliding-window variant
    (DESIGN.md Sec 4 long-context policy)."""
    if shape.name == "long_500k" and cfg.family != "ssm":
        return cfg.long_context_window
    return None


def input_specs(cfg: ModelConfig, shape: ShapeSpec, *, dtype=None) -> Dict:
    """Returns the kwargs pytree for the step function of ``shape.mode``."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    B, S = shape.global_batch, shape.seq_len
    if shape.mode == "train":
        specs = {
            "tokens": SDS((B, S), jnp.int32),
            "labels": SDS((B, S), jnp.int32),
        }
        if cfg.prefix_len:
            specs["prefix_embed"] = SDS((B, cfg.prefix_len, cfg.d_model), dtype)
        return specs
    if shape.mode == "prefill":
        specs = {"tokens": SDS((B, S), jnp.int32)}
        if cfg.prefix_len:
            specs["prefix_embed"] = SDS((B, cfg.prefix_len, cfg.d_model), dtype)
        return specs
    if shape.mode == "decode":
        wo = decode_window_override(cfg, shape)
        cache = jax.eval_shape(
            lambda: init_cache(cfg, B, S, dtype, window_override=wo)
        )
        return {"tokens": SDS((B, 1), jnp.int32), "cache": cache}
    raise ValueError(shape.mode)


def get_shape(name: str) -> ShapeSpec:
    return SHAPES[name]
