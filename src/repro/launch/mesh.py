"""Production mesh builders.

Single pod: (16, 16) = 256 v5e chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model").

Functions (not module constants) so importing never touches jax device
state — the dry-run must set XLA_FLAGS before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import math

    need = math.prod(shape)
    devices = jax.devices()[:need]
    return jax.make_mesh(shape, axes, devices=devices)


def make_debug_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for CPU-device tests (requires host platform devices)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
