"""Step builders: train / prefill / decode, with pjit shardings.

``build_*`` return (jitted_fn, example_arg_specs) pairs used both by the
real drivers (launch/train.py, launch/serve.py) and the multi-pod
dry-run (launch/dryrun.py).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeSpec
from ..core.losses import combine, nll_loss
from ..distributed.sharding import (
    batch_pspecs,
    cache_pspecs,
    needs_fsdp,
    param_pspecs,
)
from ..models.model import MelinoeRun, apply_model, decode_step, init_cache, param_shapes
from ..models.runtime import Runtime
from ..training.optim import OptConfig, adamw_update, init_opt_state
from .specs import decode_window_override, input_specs


def _shift_loss(logits, tokens, labels, prefix_len: int):
    """Next-token NLL with the prefix-embedding offset (DESIGN.md Sec 3)."""
    if prefix_len:
        pred = logits[:, prefix_len - 1 : -1]
        tgt = labels
    else:
        pred = logits[:, :-1]
        tgt = labels[:, 1:]
    return nll_loss(pred, tgt)


def make_loss_fn(cfg: ModelConfig, rt: Runtime, *, melinoe: bool):
    use_mel = melinoe and cfg.has_router and cfg.melinoe is not None

    def loss_fn(params, batch):
        mel = None
        if use_mel:
            from ..core.lora import extract_base_routers

            mel = MelinoeRun(
                spec=cfg.melinoe,
                cache_capacity=cfg.melinoe_cache_capacity(),
                base_routers=extract_base_routers(params, cfg),
            )
        logits, aux = apply_model(
            params, cfg, batch["tokens"], rt,
            prefix_embed=batch.get("prefix_embed"),
            melinoe=mel, remat=rt.sharded,
        )
        nll = _shift_loss(logits, batch["tokens"], batch["labels"], cfg.prefix_len)
        if use_mel:
            total = combine(nll, aux["cs_loss"], aux["rm_loss"], cfg.melinoe)
            metrics = {"nll": nll, "cs_loss": aux["cs_loss"],
                       "rm_loss": aux["rm_loss"], "loss": total}
        else:
            total = nll
            metrics = {"nll": nll, "loss": total}
        return total, metrics

    return loss_fn


def build_train_step(cfg: ModelConfig, rt: Runtime, opt_cfg: OptConfig, *,
                     melinoe: bool = True):
    """Full-parameter training step (pretrain / integrated-technique mode).
    fn(params, opt_state, batch) -> (params, opt_state, metrics)."""
    from ..training.optim import global_norm

    loss_fn = make_loss_fn(cfg, rt, melinoe=melinoe)

    def step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        new_params, new_opt, om = adamw_update(grads, opt_state, params, opt_cfg)
        metrics = dict(metrics, grad_norm=global_norm(grads), lr=om["lr"])
        return new_params, new_opt, metrics

    return step


def ns_tree(rt: Runtime, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(rt.mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def train_shardings(cfg: ModelConfig, rt: Runtime, batch_specs):
    """(params, opt_state, batch) shardings for the train step."""
    shapes = param_shapes(cfg)
    pspec = param_pspecs(shapes, cfg, rt)
    opt_spec = {"mu": pspec, "nu": pspec, "step": P()}
    bspec = batch_pspecs(batch_specs, rt)
    return ns_tree(rt, pspec), ns_tree(rt, opt_spec), ns_tree(rt, bspec)


def decode_shardings(cfg: ModelConfig, rt: Runtime, batch_specs):
    shapes = param_shapes(cfg)
    pspec = param_pspecs(shapes, cfg, rt)
    bspec = {
        "tokens": batch_pspecs(batch_specs["tokens"], rt),
        "cache": cache_pspecs(batch_specs["cache"], rt),
    }
    return ns_tree(rt, pspec), ns_tree(rt, bspec)


def build_prefill_step(cfg: ModelConfig, rt: Runtime, *, n_slots: Optional[int] = None,
                       window_override: Optional[int] = None):
    def step(params, batch):
        logits, aux = apply_model(
            params, cfg, batch["tokens"], rt,
            prefix_embed=batch.get("prefix_embed"),
            want_cache=True,
            cache_slots=n_slots or 0,
            window_override=window_override,
        )
        return logits[:, -1:], aux["cache"]

    return step


def build_decode_step(cfg: ModelConfig, rt: Runtime, *,
                      window_override: Optional[int] = None):
    def step(params, batch):
        logits, new_cache, _ = decode_step(
            params, cfg, batch["tokens"], batch["cache"], rt,
            window_override=window_override,
        )
        return logits, new_cache

    return step


# ---------------------------------------------------------------------------
# MELINOE fine-tuning step (router + gate + LoRA trainable; Sec 3.1.1)
# ---------------------------------------------------------------------------


def build_finetune_step(cfg: ModelConfig, rt: Runtime, opt_cfg: OptConfig, mask):
    """fn(params, lora, opt_state, batch, base_routers) ->
    (params, lora, opt_state, metrics).

    ``mask``: static bool pytree (melinoe_trainable_mask) — closed over so
    the Python bools stay static under jit. opt_state covers the
    (params, lora) pair; frozen leaves keep zero moments."""
    assert cfg.has_router and cfg.melinoe is not None
    from ..core.lora import (
        apply_mask,
        extract_base_routers,
        lora_scale,
        melinoe_trainable_mask,
    )

    spec = cfg.melinoe
    scale = lora_scale(spec)

    def loss_fn(trainable, frozen_params, batch, base_routers):
        params, lora = trainable
        mel = MelinoeRun(spec=spec, cache_capacity=cfg.melinoe_cache_capacity(),
                         base_routers=base_routers)
        logits, aux = apply_model(
            params, cfg, batch["tokens"], rt,
            prefix_embed=batch.get("prefix_embed"),
            melinoe=mel, lora=lora, lora_scale=scale,
        )
        nll = _shift_loss(logits, batch["tokens"], batch["labels"], cfg.prefix_len)
        total = combine(nll, aux["cs_loss"], aux["rm_loss"], spec)
        return total, {"nll": nll, "cs_loss": aux["cs_loss"],
                       "rm_loss": aux["rm_loss"], "loss": total}

    def step(params, lora, opt_state, batch, base_routers):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            (params, lora), params, batch, base_routers
        )
        gp, gl = grads
        # zero the frozen-partition grads BEFORE the optimizer step: their
        # updates are discarded anyway, but left in place they inflate the
        # global clip norm and shrink the router/gate/LoRA updates that
        # drive the CS loss down (Sec 3.1.1 trains only the partition)
        gp = apply_mask(gp, mask)
        lora_mask = jax.tree.map(lambda _: True, lora)
        (new_params, new_lora), new_opt, _ = adamw_update(
            (gp, gl), opt_state, (params, lora), opt_cfg, mask=(mask, lora_mask)
        )
        return new_params, new_lora, new_opt, metrics

    return step
