"""Offloaded serving launcher (post-deployment stage, Sec 3.2).

    PYTHONPATH=src python -m repro.launch.serve --arch olmoe-mini \
        --ckpt checkpoints/olmoe-mini_melinoe.ckpt --capacity 8 --policy gamma

Loads a checkpoint, optionally trains/loads the activation predictor,
and serves batched greedy requests through the offloaded expert cache,
reporting transfers and Eq.-3 modeled throughput.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..core.offload_engine import HardwareProfile, OffloadedMoEEngine
from ..core.predictor import (
    PromptEmbedder,
    init_predictor,
    predict_scores,
    train_predictor,
)
from ..data.synthetic import ClusterLM, SyntheticConfig
from ..inference.engine import routing_trace
from ..models.model import init_params
from ..training.checkpoint import load_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmoe-mini")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--capacity", type=int, default=0, help="0 => E/4")
    ap.add_argument("--policy", default="gamma", choices=["lru", "lfu", "gamma"])
    ap.add_argument("--quantized", action="store_true")
    ap.add_argument("--predictor", action="store_true", help="train + use Psi prefetch")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=64)
    ap.add_argument("--n-train-prompts", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    assert cfg.has_router, "offloaded serving applies to MoE architectures"
    if args.ckpt:
        from ..models.model import param_shapes

        like = jax.eval_shape(lambda: init_params(jax.random.key(0), cfg, jnp.float32))
        params, _, meta = load_checkpoint(args.ckpt, like)
        print(f"loaded {args.ckpt} ({meta})")
    else:
        params = init_params(jax.random.key(0), cfg, jnp.float32)
        print("using randomly initialized weights (demo mode)")

    capacity = args.capacity or cfg.melinoe_cache_capacity()
    lm = ClusterLM(SyntheticConfig(vocab=cfg.vocab, seq_len=args.prompt_len, seed=3))
    rng = np.random.default_rng(0)
    prompts = np.stack(
        [lm.sample_sequence(rng)[0] for _ in range(args.batch)]
    ).astype(np.int32)

    engine = OffloadedMoEEngine(
        cfg, params, capacity=capacity, policy=args.policy,
        quantized=args.quantized, hw=HardwareProfile(),
    )

    if args.predictor:
        emb = PromptEmbedder(cfg.vocab)
        tr_prompts = np.stack(
            [lm.sample_sequence(rng)[0] for _ in range(args.n_train_prompts)]
        ).astype(np.int32)
        _, probs = routing_trace(cfg, params, tr_prompts, max_new=16)
        targets = jnp.asarray(probs.mean(axis=2))  # (N, L, E)
        embs = jnp.stack([emb(jnp.asarray(p)) for p in tr_prompts])
        pp = init_predictor(jax.random.key(1), targets.shape[1], targets.shape[2])
        pp, hist = train_predictor(pp, embs, targets)
        print(f"predictor KL {hist[0]:.4f} -> {hist[-1]:.4f}")
        scores = predict_scores(pp, emb(jnp.asarray(prompts)).mean(0))
        engine.prefetch(scores)

    res = engine.generate(prompts, max_new_tokens=args.max_new)
    m = res["metrics"]
    print(f"generated {m.decode_tokens} tokens x batch {args.batch}")
    print(f"transfers={m.transfers} ({res['transfers_per_layer']:.1f}/layer), "
          f"prefetch={m.prefetch_transfers}")
    print(f"hit rate={res['cache_stats'].hit_rate:.3f}")
    print(f"modeled throughput={res['throughput_tok_s']:.2f} tok/s "
          f"(hw={engine.hw.name}, Eq. 3)")


if __name__ == "__main__":
    main()
