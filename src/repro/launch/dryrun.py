import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes and dump memory/cost/collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --arch olmoe --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

This module sets XLA_FLAGS *before any jax import* (512 placeholder host
devices) — do NOT import it from code that needs the real device count.
"""

import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from ..configs import SHAPES, get_config, list_archs
from ..configs.registry import ASSIGNED
from ..models.model import param_shapes
from ..models.runtime import Runtime
from ..training.optim import OptConfig, init_opt_state
from .mesh import make_production_mesh
from .specs import decode_window_override, input_specs
from .steps import (
    build_decode_step,
    build_prefill_step,
    build_train_step,
    decode_shardings,
    ns_tree,
    train_shardings,
)
from ..distributed.sharding import batch_pspecs, needs_fsdp

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _mem_dict(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    keys = (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    )
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def run_one(arch: str, shape_name: str, mesh_kind: str, *, save_hlo: bool = False,
            profile: str = "tp", out_dir: Path = None):
    out_dir = out_dir or OUT_DIR
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rt = Runtime(mesh=mesh, use_kernels=False, profile=profile)
    specs = input_specs(cfg, shape)
    pshapes = param_shapes(cfg)
    fsdp = needs_fsdp(cfg, rt)

    t0 = time.time()
    if shape.mode == "train":
        opt_cfg = OptConfig(total_steps=1000)
        step = build_train_step(cfg, rt, opt_cfg, melinoe=True)
        oshapes = jax.eval_shape(init_opt_state, pshapes)
        ps, os_, bs = train_shardings(cfg, rt, specs)
        jitted = jax.jit(step, in_shardings=(ps, os_, bs))
        lowered = jitted.lower(pshapes, oshapes, specs)
    elif shape.mode == "prefill":
        step = build_prefill_step(cfg, rt, n_slots=shape.seq_len)
        ps, _, bs = train_shardings(
            cfg, rt, {k: v for k, v in specs.items()}
        )
        jitted = jax.jit(step, in_shardings=(ps, bs))
        lowered = jitted.lower(pshapes, specs)
    else:  # decode
        wo = decode_window_override(cfg, shape)
        step = build_decode_step(cfg, rt, window_override=wo)
        ps, bs = decode_shardings(cfg, rt, specs)
        jitted = jax.jit(step, in_shardings=(ps, bs))
        lowered = jitted.lower(pshapes, specs)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = compiled.cost_analysis() or {}
    cost = {k: float(v) for k, v in cost.items() if isinstance(v, (int, float))}
    mem = _mem_dict(compiled)
    hlo = compiled.as_text()
    sys.path.insert(0, str(Path(__file__).resolve().parents[3]))
    from benchmarks.hlo_analysis import CollectiveStats, full_costs

    # full analyzer: scan(while)-body costs multiplied by trip counts —
    # XLA's cost_analysis() counts loop bodies once (see hlo_analysis.py)
    costs = full_costs(hlo)
    coll = CollectiveStats()
    coll.bytes_by_kind.update(costs.coll_by_kind)
    coll.count_by_kind.update({k: int(v) for k, v in costs.coll_counts.items()})

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "mesh_shape": dict(mesh.shape),
        "n_devices": int(mesh.devices.size),
        "fsdp": bool(fsdp),
        "mode": shape.mode,
        "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "param_counts": cfg.param_counts(),
        "flops_per_device": costs.flops,  # dot FLOPs, scan-aware
        "bytes_accessed_per_device": costs.bytes_accessed,
        "xla_flops_per_device": cost.get("flops"),  # loop bodies counted once
        "cost_analysis": cost,
        "memory_analysis": mem,
        "collectives": coll.as_dict(),
        "hlo_bytes": len(hlo),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "window_override": decode_window_override(cfg, shape),
        "profile": profile,
        "opts": os.environ.get("REPRO_OPT", ""),
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path = out_dir / f"{arch}__{shape_name}__{mesh_kind}.json"
    out_path.write_text(json.dumps(rec, indent=1))
    if save_hlo:
        (out_dir / f"{arch}__{shape_name}__{mesh_kind}.hlo.txt").write_text(hlo)
    del compiled, lowered, hlo
    jax.clear_caches()
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true", help="all assigned archs x shapes")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--profile", default="tp", choices=["tp", "pure_fsdp"])
    ap.add_argument("--out-dir", default=None, help="override output dir (opt runs)")
    args = ap.parse_args()

    archs = ASSIGNED if args.all else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                tag = f"{arch} x {shape} x {mesh_kind}"
                odir = Path(args.out_dir) if args.out_dir else OUT_DIR
                out_path = odir / f"{arch}__{shape}__{mesh_kind}.json"
                if args.skip_existing and out_path.exists():
                    print(f"[skip] {tag}")
                    continue
                try:
                    rec = run_one(arch, shape, mesh_kind, save_hlo=args.save_hlo,
                                  profile=args.profile,
                                  out_dir=Path(args.out_dir) if args.out_dir else OUT_DIR)
                    print(
                        f"[ok]   {tag}: flops/dev={rec['flops_per_device']:.3e} "
                        f"coll={rec['collectives']['total_bytes']:.3e}B "
                        f"compile={rec['compile_s']}s"
                    )
                except Exception as e:
                    failures.append((tag, repr(e)))
                    print(f"[FAIL] {tag}: {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for tag, err in failures:
            print(" ", tag, err)
        sys.exit(1)
    print("\nall dry-runs passed")


if __name__ == "__main__":
    main()
