"""Supervised serving-fleet launcher.

    # 2 workers over a Poisson trace; kill worker 0 mid-serve and let
    # the supervisor restart it from its journal
    PYTHONPATH=src python -m repro.launch.bench_fleet \
        --arch granite-moe-1b-a400m-smoke --workers 2 --n-requests 8 \
        --worker-faults "0:kill_at=4,seed=0" --dir /tmp/fleet \
        --out /tmp/fleet/report.json --prom /tmp/fleet/fleet.prom

Partitions the synthesized workload across N ``repro.fleet.worker``
processes (each with its own journal under ``--dir/worker-i/``),
supervises heartbeats, restarts crashed/hung workers, re-offers
requests from circuit-broken workers, and aggregates the journals into
one report. SIGTERM drains the whole fleet gracefully (workers finish
in-flight, checkpoint, exit 0) and still exits 0 as long as every
request is finished or checkpointed.

Exit status: 0 iff no request is unaccounted (finished nor journaled).
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import sys

from ..configs import get_config
from ..data.synthetic import ClusterLM, SyntheticConfig
from ..fleet import FleetConfig, FleetSupervisor, parse_worker_fault_schedule
from ..serving import TrafficConfig, prefill_expert_scores, synthesize_workload


def build_workload(args, cfg):
    lm = ClusterLM(SyntheticConfig(vocab=cfg.vocab,
                                   seq_len=args.prompt_len * 2,
                                   seed=args.seed + 3))
    tcfg = TrafficConfig(
        n_requests=args.n_requests, arrival=args.arrival, rate=args.rate,
        prompt_len=(max(args.prompt_len // 2, 1), args.prompt_len),
        max_new_tokens=(max(args.max_new // 2, 1), args.max_new),
        temperature=0.0, seed=args.seed,
    )
    return synthesize_workload(lm, tcfg)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-1b-a400m-smoke")
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--offloaded", action="store_true",
                    help="wave workers over the offloaded expert cache "
                         "(default: continuous slot batching)")
    ap.add_argument("--slots", type=int, default=2,
                    help="KV slots / wave size per worker")
    ap.add_argument("--capacity", type=int, default=0)
    ap.add_argument("--scheduler", default="fcfs",
                    choices=["fcfs", "sjf", "expert-affinity"])
    ap.add_argument("--engine-impl", default="slab",
                    choices=["slab", "dict"])
    ap.add_argument("--overlap", action="store_true")
    ap.add_argument("--n-requests", type=int, default=8)
    ap.add_argument("--arrival", default="poisson",
                    choices=["poisson", "bursty", "all_at_once"])
    ap.add_argument("--rate", type=float, default=4.0)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--worker-faults", default=None, metavar="SCHED",
                    help="worker-targeted fault schedule, e.g. "
                         "'0:kill_at=4,seed=0;2:hang_at=3:60' "
                         "(first incarnation only; restarts run clean)")
    ap.add_argument("--checkpoint-every", type=int, default=4)
    ap.add_argument("--retain-segments", type=int, default=2)
    ap.add_argument("--audit-every", type=int, default=0)
    ap.add_argument("--hang-deadline", type=float, default=10.0,
                    help="heartbeat staleness (s) while alive => hung "
                         "=> SIGKILL + restart")
    ap.add_argument("--degraded-after", type=float, default=3.0)
    ap.add_argument("--startup-grace", type=float, default=300.0)
    ap.add_argument("--heartbeat-s", type=float, default=0.25)
    ap.add_argument("--poll", type=float, default=0.1)
    ap.add_argument("--max-restarts", type=int, default=3)
    ap.add_argument("--max-wall", type=float, default=None,
                    help="drain the fleet after this many wall seconds")
    ap.add_argument("--dir", default="/tmp/repro_fleet", metavar="DIR",
                    help="fleet root; one subdirectory per worker")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the aggregated fleet report JSON")
    ap.add_argument("--prom", default=None, metavar="PATH",
                    help="write the supervisor Prometheus snapshot")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    requests = build_workload(args, cfg)
    if args.offloaded:
        assert cfg.has_router, "offloaded fleet needs a MoE arch"
        import jax
        import jax.numpy as jnp
        from ..models.model import init_params
        params = init_params(jax.random.key(0), cfg, jnp.float32)
        prefill_expert_scores(cfg, params, requests)  # ride in the trace

    fcfg = FleetConfig(
        n_workers=args.workers, arch=args.arch,
        mode="wave" if args.offloaded else "continuous",
        slots=args.slots, capacity=args.capacity,
        scheduler=args.scheduler, seed=args.seed, param_seed=0,
        overlap=args.overlap, engine_impl=args.engine_impl,
        checkpoint_every=args.checkpoint_every,
        retain_segments=args.retain_segments,
        audit_every=args.audit_every, heartbeat_s=args.heartbeat_s,
        poll_s=args.poll, hang_deadline_s=args.hang_deadline,
        degraded_after_s=args.degraded_after,
        startup_grace_s=args.startup_grace,
        max_restarts=args.max_restarts,
        worker_faults=parse_worker_fault_schedule(args.worker_faults),
    )
    sup = FleetSupervisor(requests, fcfg, args.dir)
    prev = signal.signal(signal.SIGTERM, lambda *_: sup.request_drain())
    try:
        report = sup.run(max_wall_s=args.max_wall)
    finally:
        signal.signal(signal.SIGTERM, prev)

    prom = sup.prometheus_text()
    if args.prom:
        os.makedirs(os.path.dirname(args.prom) or ".", exist_ok=True)
        with open(args.prom, "w", encoding="utf-8") as f:
            f.write(prom)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w", encoding="utf-8") as f:
            json.dump(report, f, indent=2)

    print(f"fleet: {report['n_workers']} workers, "
          f"{report['finished']}/{report['n_requests']} finished, "
          f"{len(report['pending_checkpointed'])} checkpointed-pending, "
          f"{len(report['unaccounted'])} unaccounted"
          + (" [drained]" if report["drained"] else ""))
    print(f"restarts: {report['restarts']}  "
          f"reassigned: {report['reassigned']:.0f}  "
          f"failover_s: {report['failover_s']['samples']}")
    for w in report["workers"]:
        print(f"  worker-{w['idx']}: restarts={w['restarts']} "
              f"exit={w['exit_code']} phase={w['phase']}"
              + (" FAILED" if w["failed"] else ""))
    if report["unaccounted"]:
        print(f"LOST REQUESTS: {report['unaccounted']}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
