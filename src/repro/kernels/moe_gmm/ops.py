"""Dispatching wrapper for the grouped expert matmul (auto tile selection
+ fallback to the oracle for degenerate shapes)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..dispatch import pick_tile, resolve
from .kernel import gmm as _gmm_kernel
from .ref import gmm_ref


@functools.partial(jax.jit, static_argnames=("interpret",))
def _gmm_pallas(a, b, interpret: bool):
    E, M, K = a.shape
    N = b.shape[-1]
    bm = pick_tile(max(M, 1), 128)
    bn = pick_tile(N, 128)
    bk = pick_tile(K, 512)
    return _gmm_kernel(a, b, bm=bm, bn=bn, bk=bk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _gmm_pallas_ragged(a, b, sizes, interpret: bool):
    E, M, K = a.shape
    N = b.shape[-1]
    bm = pick_tile(max(M, 1), 128)
    bn = pick_tile(N, 128)
    bk = pick_tile(K, 512)
    return _gmm_kernel(a, b, bm=bm, bn=bn, bk=bk, interpret=interpret,
                       group_sizes=sizes)


def gmm(a, b, interpret: Optional[bool] = None, use_ref: bool = False,
        backend: Optional[str] = None, group_sizes=None):
    """a (E, M, K) @ b (E, K, N) -> (E, M, N).

    ``backend``: "ref" | "pallas" | "auto" (None keeps the legacy
    ``use_ref``/``interpret`` semantics, resolving "pallas").

    ``group_sizes`` (E,): valid row counts per group. Rows past the count
    must already be zero in ``a`` (slot-dispatch buffers guarantee this);
    the Pallas path then skips M-tiles of empty/short groups. The
    reference path is oblivious (zero rows contribute zeros)."""
    E, M, K = a.shape
    N = b.shape[-1]
    choice = resolve("moe_gmm", backend or ("ref" if use_ref else "pallas"),
                     interpret=interpret)
    if not choice.use_pallas or M * N * K == 0:
        return gmm_ref(a, b)
    if group_sizes is None:
        return _gmm_pallas(a, b, choice.interpret)
    return _gmm_pallas_ragged(a, b, jnp.asarray(group_sizes, jnp.int32),
                              choice.interpret)
