"""Jit'd wrapper for the grouped expert matmul (auto tile selection +
fallback to the oracle for shapes below tiling thresholds)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import gmm as _gmm_kernel
from .ref import gmm_ref


def _pick(v: int, pref: int) -> int:
    """Largest divisor of v that is <= pref (tile picker)."""
    t = min(pref, v)
    while v % t:
        t -= 1
    return max(t, 1)


@functools.partial(jax.jit, static_argnames=("interpret", "use_ref"))
def gmm(a, b, interpret: bool = True, use_ref: bool = False):
    """a (E, M, K) @ b (E, K, N) -> (E, M, N)."""
    E, M, K = a.shape
    N = b.shape[-1]
    if use_ref or M * N * K == 0:
        return gmm_ref(a, b)
    bm = _pick(max(M, 1), 128)
    bn = _pick(N, 128)
    bk = _pick(K, 512)
    return _gmm_kernel(a, b, bm=bm, bn=bn, bk=bk, interpret=interpret)
