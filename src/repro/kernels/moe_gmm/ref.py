"""Pure-jnp oracle for the grouped expert matmul."""
import jax.numpy as jnp


def gmm_ref(a, b):
    """a (E, M, K), b (E, K, N) -> (E, M, N)."""
    return jnp.einsum("emk,ekn->emn", a.astype(jnp.float32),
                      b.astype(jnp.float32)).astype(a.dtype)
