"""Pallas TPU kernel: grouped (per-expert) matmul for the expert-parallel
MoE FFN — y[e] = a[e] @ b[e] for e in [E_local].

This is the compute hot-spot after the dispatch all_to_all: each model
shard runs its E/ms experts over the gathered (ms * cap) token rows.
Grid (E, M/bm, N/bn, K/bk), K innermost, fp32 VMEM accumulator;
MXU-aligned 128x128 output tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..dispatch import compiler_params


def _kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int, out_dtype):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[0]  # (bm, bk)
    b = b_ref[0]  # (bk, bn)
    acc_ref[...] += jax.lax.dot_general(
        a.astype(jnp.float32), b.astype(jnp.float32),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(3) == n_k - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(out_dtype)


def _kernel_ragged(s_ref, a_ref, b_ref, o_ref, acc_ref, *, n_k: int, bm: int,
                   out_dtype):
    """Ragged-group variant: ``s_ref`` (E,) scalar-prefetched row counts.
    M-tiles entirely past group e's row count skip the MXU work (rows
    >= size are required to be zero in ``a``, as the slot-dispatch
    buffers guarantee, so the zero accumulator IS the right output)."""
    e, i = pl.program_id(0), pl.program_id(1)

    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(i * bm < s_ref[e])
    def _compute():
        acc_ref[...] += jax.lax.dot_general(
            a_ref[0].astype(jnp.float32), b_ref[0].astype(jnp.float32),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(pl.program_id(3) == n_k - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(out_dtype)


def gmm(
    a: jax.Array,  # (E, M, K)
    b: jax.Array,  # (E, K, N)
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool = False,
    group_sizes: jax.Array | None = None,  # (E,) valid rows per group
) -> jax.Array:
    """Grouped matmul. With ``group_sizes``, rows >= group_sizes[e] of
    ``a[e]`` MUST be zero (slot-dispatch buffers are zero-padded); the
    kernel then skips every M-tile past the group's row count — empty
    cache slots cost no MXU work."""
    E, M, K = a.shape
    _, _, N = b.shape
    assert b.shape == (E, K, N)
    bm = min(bm, M)
    bn = min(bn, N)
    bk = min(bk, K)
    # pad M to a tile multiple (caps are often ragged)
    padm = (-M) % bm
    if padm:
        a = jnp.pad(a, ((0, 0), (0, padm), (0, 0)))
        M = M + padm
    assert N % bn == 0 and K % bk == 0, (N, K, bn, bk)
    n_k = K // bk
    grid = (E, M // bm, N // bn, n_k)
    out_dtype = a.dtype
    out_shape = jax.ShapeDtypeStruct((E, M, N), out_dtype)
    params = compiler_params(
        dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
    )
    if group_sizes is None:
        kernel = functools.partial(_kernel, n_k=n_k, out_dtype=out_dtype)
        out = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bm, bk), lambda e, i, j, k: (e, i, k)),
                pl.BlockSpec((1, bk, bn), lambda e, i, j, k: (e, k, j)),
            ],
            out_specs=pl.BlockSpec((1, bm, bn), lambda e, i, j, k: (e, i, j)),
            out_shape=out_shape,
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
            **params,
            interpret=interpret,
        )(a, b)
    else:
        kernel = functools.partial(_kernel_ragged, n_k=n_k, bm=bm,
                                   out_dtype=out_dtype)
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bm, bk), lambda e, i, j, k, s: (e, i, k)),
                pl.BlockSpec((1, bk, bn), lambda e, i, j, k, s: (e, k, j)),
            ],
            out_specs=pl.BlockSpec((1, bm, bn), lambda e, i, j, k, s: (e, i, j)),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        )
        out = pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=out_shape,
            **params,
            interpret=interpret,
        )(jnp.asarray(group_sizes, jnp.int32), a, b)
    return out[:, : M - padm] if padm else out
