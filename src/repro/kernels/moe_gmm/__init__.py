from . import ops, ref
from .kernel import gmm as gmm_kernel
from .ops import gmm
from .ref import gmm_ref

__all__ = ["ops", "ref", "gmm_kernel", "gmm", "gmm_ref"]
