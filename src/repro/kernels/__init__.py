"""Pallas TPU kernels for the compute hot-spots (validated in
interpret=True mode on CPU; see tests/test_kernels.py):

  int4_matmul — fused HQQ-INT4 dequant matmul (quantized resident experts)
  moe_gmm     — grouped per-expert FFN matmul (expert-parallel MoE)
  ssd_scan    — Mamba2 SSD chunked scan with VMEM-carried state
  flash_attn  — causal GQA flash attention fwd (prefill; VMEM-resident KV)

``dispatch`` owns backend selection (ref | pallas | auto), platform
autodetection (interpret off-TPU) and the pltpu.CompilerParams
version-compat shim shared by all four families.
"""
from . import dispatch, flash_attn, int4_matmul, moe_gmm, ssd_scan

__all__ = ["dispatch", "flash_attn", "int4_matmul", "moe_gmm", "ssd_scan"]
