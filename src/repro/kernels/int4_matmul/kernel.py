"""Pallas TPU kernel: fused INT4-dequant matmul  y = x @ dequant(Wq).

The paper keeps resident experts in HQQ INT4 (Sec 3.2); on TPU the
dequantization must be fused into the matmul so the MXU streams bf16
tiles straight out of VMEM instead of materializing the full-precision
weight in HBM.

Storage layout (see ops.quantize_matmul_weight):
  packed (K//2, N) uint8 — two 4-bit codes per byte along K
  scale/zero (K//group, N) f32 — per-group affine along K

Tiling: grid (M/bm, N/bn, K/bk), K innermost; fp32 accumulator in VMEM
scratch; MXU-aligned defaults bm=bn=128, bk=512 (bk multiple of 2*group).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..dispatch import compiler_params


def _kernel(x_ref, packed_ref, scale_ref, zero_ref, o_ref, acc_ref, *,
            group: int, n_k: int, out_dtype):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]  # (bm, bk)
    packed = packed_ref[...]  # (bk//2, bn) uint8
    lo = (packed & 0x0F).astype(jnp.float32)
    hi = (packed >> 4).astype(jnp.float32)
    bk2, bn = packed.shape
    q = jnp.stack([lo, hi], axis=1).reshape(bk2 * 2, bn)  # (bk, bn)
    scale = scale_ref[...]  # (bk//group, bn)
    zero = zero_ref[...]
    scale_full = jnp.repeat(scale, group, axis=0)  # (bk, bn)
    zero_full = jnp.repeat(zero, group, axis=0)
    w = (q - zero_full) * scale_full  # fp32 dequant
    acc_ref[...] += jax.lax.dot_general(
        x.astype(jnp.float32), w,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def int4_matmul(
    x: jax.Array,  # (M, K)
    packed: jax.Array,  # (K//2, N) uint8
    scale: jax.Array,  # (K//group, N) f32
    zero: jax.Array,  # (K//group, N) f32
    *,
    group: int = 64,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    M, K = x.shape
    N = packed.shape[1]
    assert packed.shape[0] == K // 2 and K % group == 0
    bm = min(bm, M)
    bn = min(bn, N)
    bk = min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    assert bk % (2 * group) == 0 or bk == K, "bk must cover whole groups"
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)
    out_dtype = x.dtype
    kernel = functools.partial(_kernel, group=group, n_k=n_k, out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk // group, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk // group, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        **compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(x, packed, scale, zero)
