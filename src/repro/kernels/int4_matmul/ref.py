"""Pure-jnp oracle for the INT4 dequant matmul kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dequant_ref(packed, scale, zero, group: int) -> jax.Array:
    """packed (K//2, N) uint8 -> W (K, N) f32."""
    lo = (packed & 0x0F).astype(jnp.float32)
    hi = (packed >> 4).astype(jnp.float32)
    K2, N = packed.shape
    q = jnp.stack([lo, hi], axis=1).reshape(K2 * 2, N)
    scale_full = jnp.repeat(scale, group, axis=0)
    zero_full = jnp.repeat(zero, group, axis=0)
    return (q - zero_full) * scale_full


def int4_matmul_ref(x, packed, scale, zero, group: int) -> jax.Array:
    w = dequant_ref(packed, scale, zero, group)
    return (x.astype(jnp.float32) @ w).astype(x.dtype)
