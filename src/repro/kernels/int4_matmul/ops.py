"""Jit'd wrapper + weight preparation for the INT4 dequant matmul."""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernel import int4_matmul as _kernel_call
from .ref import int4_matmul_ref


class MatmulQWeight(NamedTuple):
    packed: jax.Array  # (K//2, N) uint8
    scale: jax.Array  # (K//group, N) f32
    zero: jax.Array  # (K//group, N) f32
    group: int


def quantize_matmul_weight(w: jax.Array, group: int = 64) -> MatmulQWeight:
    """w (K, N) -> per-(group-of-K, column) affine int4 codes (min/max init;
    HQQ refinement lives in core.quant — this layout is the kernel's)."""
    K, N = w.shape
    assert K % group == 0 and K % 2 == 0
    wg = w.astype(jnp.float32).reshape(K // group, group, N)
    wmin = wg.min(1)
    wmax = wg.max(1)
    scale = jnp.maximum((wmax - wmin) / 15.0, 1e-8)  # (K//group, N)
    zero = -wmin / scale
    q = jnp.clip(
        jnp.round(wg / scale[:, None] + zero[:, None]), 0, 15
    ).astype(jnp.uint8).reshape(K, N)
    packed = (q[0::2] | (q[1::2] << 4)).astype(jnp.uint8)
    return MatmulQWeight(packed, scale, zero, group)


@functools.partial(jax.jit, static_argnames=("group", "bm", "bn", "bk", "interpret", "use_ref"))
def int4_matmul(x, packed, scale, zero, *, group: int = 64, bm: int = 128,
                bn: int = 128, bk: int = 512, interpret: bool = True,
                use_ref: bool = False):
    """y = x @ dequant(Wq). x (M, K) or (..., K) (leading dims flattened)."""
    lead = x.shape[:-1]
    K = x.shape[-1]
    x2 = x.reshape(-1, K)
    if use_ref:
        out = int4_matmul_ref(x2, packed, scale, zero, group)
    else:
        out = _kernel_call(x2, packed, scale, zero, group=group, bm=bm, bn=bn,
                           bk=bk, interpret=interpret)
    return out.reshape(*lead, -1)
