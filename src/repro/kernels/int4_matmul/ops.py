"""Dispatching wrapper + weight preparation for the INT4 dequant matmul."""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..dispatch import pick_tile, resolve
from .kernel import int4_matmul as _kernel_call
from .ref import int4_matmul_ref


class MatmulQWeight(NamedTuple):
    packed: jax.Array  # (K//2, N) uint8
    scale: jax.Array  # (K//group, N) f32
    zero: jax.Array  # (K//group, N) f32
    group: int


def quantize_matmul_weight(w: jax.Array, group: int = 64) -> MatmulQWeight:
    """w (K, N) -> per-(group-of-K, column) affine int4 codes (min/max init;
    HQQ refinement lives in core.quant — this layout is the kernel's)."""
    K, N = w.shape
    assert K % group == 0 and K % 2 == 0
    wg = w.astype(jnp.float32).reshape(K // group, group, N)
    wmin = wg.min(1)
    wmax = wg.max(1)
    scale = jnp.maximum((wmax - wmin) / 15.0, 1e-8)  # (K//group, N)
    zero = -wmin / scale
    q = jnp.clip(
        jnp.round(wg / scale[:, None] + zero[:, None]), 0, 15
    ).astype(jnp.uint8).reshape(K, N)
    packed = (q[0::2] | (q[1::2] << 4)).astype(jnp.uint8)
    return MatmulQWeight(packed, scale, zero, group)


@functools.partial(jax.jit, static_argnames=("group", "bm", "bn", "bk", "interpret"))
def _int4_pallas(x2, packed, scale, zero, group, bm, bn, bk, interpret):
    return _kernel_call(x2, packed, scale, zero, group=group, bm=bm, bn=bn,
                        bk=bk, interpret=interpret)


def int4_matmul(x, packed, scale, zero, *, group: int = 64,
                bm: Optional[int] = None, bn: Optional[int] = None,
                bk: Optional[int] = None, interpret: Optional[bool] = None,
                use_ref: bool = False, backend: Optional[str] = None):
    """y = x @ dequant(Wq). x (M, K) or (..., K) (leading dims flattened).

    Tile sizes default to the largest MXU-friendly divisors; ``bk`` is
    rounded to whole quantization groups."""
    lead = x.shape[:-1]
    K = x.shape[-1]
    x2 = x.reshape(-1, K)
    group = int(group)  # static jit arg; reject stray 0-d arrays
    choice = resolve("int4_matmul", backend or ("ref" if use_ref else "pallas"),
                     interpret=interpret)
    if not choice.use_pallas:
        out = int4_matmul_ref(x2, packed, scale, zero, group)
        return out.reshape(*lead, -1)
    M = x2.shape[0]
    N = packed.shape[1]
    if bm is None:
        bm = pick_tile(max(M, 1), 128)
    if bn is None:
        bn = pick_tile(N, 128)
    if bk is None:
        # bk must cover whole (pairs of) groups: step in 2*group units
        step = 2 * group
        bk = step * pick_tile(K // step, max(512 // step, 1)) if K % step == 0 else K
    out = _int4_pallas(x2, packed, scale, zero, group, bm, bn, bk,
                       choice.interpret)
    return out.reshape(*lead, -1)
