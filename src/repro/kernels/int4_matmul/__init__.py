from . import ops, ref
from .kernel import int4_matmul as int4_matmul_kernel
from .ops import MatmulQWeight, int4_matmul, quantize_matmul_weight

__all__ = [
    "ops",
    "ref",
    "int4_matmul_kernel",
    "MatmulQWeight",
    "int4_matmul",
    "quantize_matmul_weight",
]
