from . import ops, ref
from .kernel import flash_attention_fwd
from .ops import flash
from .ref import attention_ref

__all__ = ["ops", "ref", "flash_attention_fwd", "flash", "attention_ref"]
