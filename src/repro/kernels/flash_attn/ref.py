"""Pure-jnp oracle for the flash-attention kernel: materialized-scores
causal attention with GQA, softcap and sliding window."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, softcap: Optional[float] = None,
                  window: Optional[int] = None):
    """q (B,T,Hkv,G,hd); k/v (B,S,Hkv,hd) -> (B,T,Hkv,G,hd)."""
    B, T, Hkv, G, hd = q.shape
    S = k.shape[1]
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd**-0.5
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    qp = jnp.arange(T)[:, None]
    kp = jnp.arange(S)[None, :]
    mask = kp <= qp
    if window is not None:
        mask &= kp > qp - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
