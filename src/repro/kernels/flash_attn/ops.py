"""Dispatching wrapper for the flash-attention kernel.

NOTE: this kernel keeps the full K/V for one kv-head resident in VMEM
(block = (1, S, 1, hd)) — correct and MXU-aligned for S*hd*4B within the
~16 MB VMEM budget (S <= ~8k at hd=128, <= ~16k at hd=64). Longer
sequences use the pure-JAX blockwise path in models/attention.py, which
streams KV from HBM; a production double-buffered DMA variant is the
natural next kernel iteration. ``supported()`` encodes that envelope so
``auto`` dispatch can bail out to the reference path.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from ..dispatch import resolve
from .kernel import flash_attention_fwd
from .ref import attention_ref

# VMEM envelope for the compiled kernel: one kv-head's K+V in fp32 plus
# headroom for q/out/scratch must fit in ~16 MB.
_VMEM_KV_BUDGET = 8 * 1024 * 1024


def supported(q_shape, k_shape, interpret: bool) -> bool:
    """Can the kernel handle these shapes? (interpret mode: always;
    compiled: KV for one head must fit the VMEM residency budget)."""
    if interpret:
        return True
    B, S, Hkv, hd = k_shape
    return 2 * S * hd * 4 <= _VMEM_KV_BUDGET


@functools.partial(jax.jit, static_argnames=("softcap", "window", "bq", "bk",
                                             "interpret"))
def _flash_pallas(q, k, v, softcap, window, bq, bk, interpret):
    T, S = q.shape[1], k.shape[1]
    while T % bq:
        bq //= 2
    while S % bk:
        bk //= 2
    return flash_attention_fwd(q, k, v, softcap=softcap, window=window,
                               bq=max(bq, 1), bk=max(bk, 1), interpret=interpret)


def flash(q, k, v, *, softcap: Optional[float] = None,
          window: Optional[int] = None, bq: int = 256, bk: int = 256,
          interpret: Optional[bool] = None, use_ref: bool = False,
          backend: Optional[str] = None):
    """Causal GQA attention. q (B,T,Hkv,G,hd); k/v (B,S,Hkv,hd)."""
    choice = resolve("flash_attn", backend or ("ref" if use_ref else "pallas"),
                     interpret=interpret)
    if not choice.use_pallas or not supported(q.shape, k.shape, choice.interpret):
        return attention_ref(q, k, v, softcap=softcap, window=window)
    return _flash_pallas(q, k, v, softcap, window, bq, bk, choice.interpret)
