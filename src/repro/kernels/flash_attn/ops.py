"""Jit'd wrapper for the flash-attention kernel.

NOTE: this kernel keeps the full K/V for one kv-head resident in VMEM
(block = (1, S, 1, hd)) — correct and MXU-aligned for S*hd*4B within the
~16 MB VMEM budget (S <= ~8k at hd=128, <= ~16k at hd=64). Longer
sequences use the pure-JAX blockwise path in models/attention.py, which
streams KV from HBM; a production double-buffered DMA variant is the
natural next kernel iteration.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from .kernel import flash_attention_fwd
from .ref import attention_ref


@functools.partial(jax.jit, static_argnames=("softcap", "window", "bq", "bk",
                                             "interpret", "use_ref"))
def flash(q, k, v, *, softcap: Optional[float] = None,
          window: Optional[int] = None, bq: int = 256, bk: int = 256,
          interpret: bool = True, use_ref: bool = False):
    if use_ref:
        return attention_ref(q, k, v, softcap=softcap, window=window)
    T, S = q.shape[1], k.shape[1]
    while T % bq:
        bq //= 2
    while S % bk:
        bk //= 2
    return flash_attention_fwd(q, k, v, softcap=softcap, window=window,
                               bq=max(bq, 1), bk=max(bk, 1), interpret=interpret)
