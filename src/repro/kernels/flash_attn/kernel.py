"""Pallas TPU kernel: causal flash attention (forward), GQA-aware.

Grid (B, Hkv, nq) with the q-chunk dimension parallel and an inner
fori_loop over KV chunks; online-softmax running stats (m, l) and the
output accumulator live in VMEM scratch. Only the causally-visible KV
chunks are visited per q chunk (no masked-rectangle waste — unlike the
pure-JAX fallback, which computes the full rectangle under scan).

Supports: GQA (G q-heads per kv head processed together as a (G*bq, hd)
block), score softcap (gemma2), sliding-window masking.

Layouts: q (B, T, Hkv, G, hd); k/v (B, S, Hkv, hd); out like q.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..dispatch import compiler_params

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq: int, bk: int, n_kv: int, scale: float, softcap: Optional[float],
            window: Optional[int], out_dtype):
    qi = pl.program_id(2)
    q = q_ref[0, :, 0].astype(jnp.float32)  # (bq*G, hd) flattened q block
    G = q.shape[0] // bq

    m_ref[...] = jnp.full_like(m_ref, NEG)
    l_ref[...] = jnp.zeros_like(l_ref)
    acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)  # (bq,1)
    # visit kv chunks up to the causal frontier (and within the window)
    hi = jnp.minimum((qi + 1) * bq, n_kv * bk)
    n_vis = pl.cdiv(hi, bk)
    lo = 0
    if window is not None:
        lo = jnp.maximum((qi * bq - window + 1) // bk, 0)

    def body(j, _):
        # NB: full slices on the singleton dims (an int index here breaks
        # the interpret-mode discharge rule on jax 0.4.x)
        ksl = (slice(None), pl.dslice(j * bk, bk), slice(None), slice(None))
        k = pl.load(k_ref, ksl)[0, :, 0].astype(jnp.float32)
        v = pl.load(v_ref, ksl)[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale  # (bq*G, bk)
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)  # (1,bk)
        mask = k_pos <= q_pos  # causal, per q row
        if window is not None:
            mask &= k_pos > (q_pos - window)
        mask_g = jnp.repeat(mask, G, axis=0) if G > 1 else mask
        s = jnp.where(mask_g, s, NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new
        return 0

    jax.lax.fori_loop(lo, n_vis, body, 0)
    out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
    o_ref[0, :, 0] = out.astype(out_dtype)


def flash_attention_fwd(
    q: jax.Array,  # (B, T, Hkv, G, hd)
    k: jax.Array,  # (B, S, Hkv, hd)
    v: jax.Array,
    *,
    softcap: Optional[float] = None,
    window: Optional[int] = None,
    bq: int = 256,
    bk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    B, T, Hkv, G, hd = q.shape
    S = k.shape[1]
    assert T % min(bq, T) == 0 and S % min(bk, S) == 0, (T, S, bq, bk)
    bq = min(bq, T)
    bk = min(bk, S)
    n_q = T // bq
    n_kv = S // bk
    scale = hd**-0.5
    # flatten (T, G) -> token-major rows so the MXU sees one (bq*G, hd)
    # matmul per chunk; row index = t*G + g
    qf = q.transpose(0, 1, 3, 2, 4).reshape(B, T * G, Hkv, hd)

    kernel = functools.partial(
        _kernel, bq=bq, bk=bk, n_kv=n_kv, scale=scale, softcap=softcap,
        window=window, out_dtype=q.dtype,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, n_q),
        in_specs=[
            pl.BlockSpec((1, bq * G, 1, hd), lambda b, h, i: (b, i, h, 0)),
            pl.BlockSpec((1, S, 1, hd), lambda b, h, i: (b, 0, h, 0)),
            pl.BlockSpec((1, S, 1, hd), lambda b, h, i: (b, 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq * G, 1, hd), lambda b, h, i: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T * G, Hkv, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq * G, 1), jnp.float32),
            pltpu.VMEM((bq * G, 1), jnp.float32),
            pltpu.VMEM((bq * G, hd), jnp.float32),
        ],
        **compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel")
        ),
        interpret=interpret,
    )(qf, k, v)
    # (B, T*G, Hkv, hd) -> (B, T, Hkv, G, hd)
    return out.reshape(B, T, G, Hkv, hd).transpose(0, 1, 3, 2, 4)
