"""Kernel-dispatch subsystem: one place that decides, per op, whether the
hot path runs the Pallas kernel or the pure-jnp reference, and in which
execution mode.

Three concerns the kernel families previously hand-threaded (and got
wrong — the `pltpu.CompilerParams` AttributeError hid the whole layer):

  * JAX version compat — the pinned 0.4.x exposes
    ``pltpu.TPUCompilerParams``; newer releases renamed it to
    ``pltpu.CompilerParams`` (and dropped ``dimension_semantics``).
    :func:`compiler_params` returns the right kwargs for ``pl.pallas_call``
    on whatever is installed, degrading to "no params" when neither
    exists (pure interpret-mode environments).

  * platform autodetection — compiled Pallas on TPU, ``interpret=True``
    everywhere else, so callers never pass ``interpret=`` by hand.

  * a per-op backend registry — every op resolves a spec string
    ``"ref" | "pallas" | "auto"`` (optionally per-op:
    ``"ref,moe_gmm=pallas"``) into a concrete :class:`KernelChoice`.
    ``auto`` means "run the Pallas kernel wherever it supports the
    shapes: compiled on TPU, interpret elsewhere". The environment
    variable ``REPRO_KERNEL_BACKEND`` overrides whatever the caller
    (usually ``Runtime.kernel_backend``) configured.
"""
from __future__ import annotations

import os
from typing import NamedTuple, Optional

import jax

# Every kernel family registered with the dispatcher. Consumers ask for
# one of these names; unknown names are an error so typos fail loudly.
OPS = ("flash_attn", "int4_matmul", "moe_gmm", "ssd_scan")

BACKENDS = ("ref", "pallas", "auto")

ENV_VAR = "REPRO_KERNEL_BACKEND"


# ---------------------------------------------------------------------------
# JAX version-compat shim
# ---------------------------------------------------------------------------


def _compiler_params_cls():
    try:
        from jax.experimental.pallas import tpu as pltpu
    except ImportError:  # pragma: no cover - pallas always present in-tree
        return None
    return getattr(pltpu, "TPUCompilerParams", None) or getattr(
        pltpu, "CompilerParams", None
    )


def compiler_params(dimension_semantics=None, **kw) -> dict:
    """Version-portable ``compiler_params=`` kwargs for ``pl.pallas_call``.

    Usage: ``pl.pallas_call(..., **compiler_params(dimension_semantics=(...)))``.
    Returns ``{}`` when no params class exists or when the installed class
    rejects the requested fields (they are performance hints, never
    correctness requirements).
    """
    cls = _compiler_params_cls()
    if cls is None:
        return {}
    if dimension_semantics is not None:
        kw = dict(kw, dimension_semantics=tuple(dimension_semantics))
    try:
        return {"compiler_params": cls(**kw)}
    except TypeError:
        kw.pop("dimension_semantics", None)
        try:
            return {"compiler_params": cls(**kw)} if kw else {}
        except TypeError:
            return {}


def pick_tile(v: int, pref: int) -> int:
    """Largest divisor of ``v`` that is <= ``pref`` — the shared tile
    picker (grids must divide the array dims exactly)."""
    t = min(pref, v)
    while v % t:
        t -= 1
    return max(t, 1)


# ---------------------------------------------------------------------------
# Platform autodetection
# ---------------------------------------------------------------------------


def default_platform() -> str:
    """'tpu' | 'gpu' | 'cpu' — the platform kernels would execute on."""
    return jax.default_backend()


def interpret_default(platform: Optional[str] = None) -> bool:
    """Pallas TPU kernels compile only on TPU; everywhere else they run
    under the (slow but exact) interpreter."""
    return (platform or default_platform()) != "tpu"


# ---------------------------------------------------------------------------
# Per-op backend resolution
# ---------------------------------------------------------------------------


class KernelChoice(NamedTuple):
    backend: str  # "ref" | "pallas"
    interpret: bool  # meaningful only when backend == "pallas"

    @property
    def use_pallas(self) -> bool:
        return self.backend == "pallas"


def parse_spec(spec: Optional[str]) -> dict:
    """``"auto"`` / ``"ref,moe_gmm=pallas"`` -> {"*": ..., op: ...}.

    A bare backend name sets the global default ("*"); ``op=backend``
    entries override per op. Only explicitly-named keys appear in the
    result (callers supply the "ref" fallback). Whitespace-tolerant.
    Unknown ops/backends raise.
    """
    out: dict = {}
    if not spec:
        return out
    for part in str(spec).split(","):
        part = part.strip()
        if not part:
            continue
        if "=" in part:
            op, _, backend = part.partition("=")
            op, backend = op.strip(), backend.strip()
            if op not in OPS:
                raise ValueError(f"unknown kernel op {op!r} (known: {OPS})")
        else:
            op, backend = "*", part
        if backend not in BACKENDS:
            raise ValueError(
                f"unknown kernel backend {backend!r} (known: {BACKENDS})"
            )
        out[op] = backend
    return out


def op_backend(op: str, spec: Optional[str]) -> str:
    """The configured backend ("ref"|"pallas"|"auto") for ``op`` under
    ``spec``, after applying the ``REPRO_KERNEL_BACKEND`` env override.

    Env entries win per key: a per-op-only override (``flash_attn=ref``)
    adjusts that op and leaves the caller's spec in force for the rest;
    a bare backend name overrides the global default."""
    if op not in OPS:
        raise ValueError(f"unknown kernel op {op!r} (known: {OPS})")
    table = parse_spec(spec)
    env = os.environ.get(ENV_VAR)
    if env:
        table.update(parse_spec(env))
    return table.get(op, table.get("*", "ref"))


def resolve(
    op: str,
    spec: Optional[str] = None,
    *,
    interpret: Optional[bool] = None,
    platform: Optional[str] = None,
) -> KernelChoice:
    """Resolve (op, backend spec) -> concrete :class:`KernelChoice`.

    ``interpret=None`` autodetects from the platform; an explicit bool is
    honoured (tests force interpret=True regardless of platform).
    """
    backend = op_backend(op, spec)
    if backend == "auto":
        backend = "pallas"
    if backend == "ref":
        choice = KernelChoice("ref", False)
    else:
        if interpret is None:
            interpret = interpret_default(platform)
        choice = KernelChoice("pallas", bool(interpret))
    _record_dispatch(op, choice)
    return choice


def _record_dispatch(op: str, choice: KernelChoice) -> None:
    """Observability tap on backend selection: a labeled counter (always)
    plus a trace instant (when tracing is enabled)."""
    from ..obs.registry import REGISTRY
    from ..obs.trace import get_tracer

    REGISTRY.counter(
        "kernel_dispatch_total", "kernel backend selections by resolve()",
        op=op, backend=choice.backend, interpret=choice.interpret,
    ).inc()
    tr = get_tracer()
    if tr.enabled:
        tr.instant("kernel.dispatch", op=op, backend=choice.backend,
                   interpret=choice.interpret)
