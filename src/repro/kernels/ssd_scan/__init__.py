from . import ops, ref
from .kernel import ssd_scan as ssd_scan_kernel
from .ops import ssd
from .ref import ssd_scan_ref

__all__ = ["ops", "ref", "ssd_scan_kernel", "ssd", "ssd_scan_ref"]
