"""Pure-jnp oracle: sequential SSM recurrence (the exact linear-time
definition the SSD chunked algorithm must reproduce)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def ssd_scan_ref(x, dt, A, Bm, Cm, init=None):
    """Sequential scan. x (B,T,H,P); dt (B,T,H); A (H,);
    Bm/Cm (B,T,N) shared across heads or (B,T,G,N) per-group
    (head h uses group h // (H//G)); ``init`` (B,H,P,N) optional state.

    s_t = exp(dt_t A) s_{t-1} + dt_t * x_t B_t^T ;  y_t = s_t C_t
    Returns (y (B,T,H,P), final state (B,H,P,N))."""
    B, T, H, P = x.shape
    if Bm.ndim == 3:  # shared across heads
        Bm = Bm[:, :, None]
        Cm = Cm[:, :, None]
    G, N = Bm.shape[-2:]
    hpg = H // G
    # expand groups to per-head (B,T,H,N)
    Bh = jnp.repeat(Bm, hpg, axis=2)
    Ch = jnp.repeat(Cm, hpg, axis=2)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = Bh.astype(jnp.float32)
    Cf = Ch.astype(jnp.float32)

    def body(s, inp):
        x_t, dt_t, b_t, c_t = inp  # (B,H,P), (B,H), (B,H,N), (B,H,N)
        dec = jnp.exp(dt_t * A[None])  # (B,H)
        s = s * dec[..., None, None] + jnp.einsum(
            "bhp,bhn,bh->bhpn", x_t, b_t, dt_t
        )
        y = jnp.einsum("bhpn,bhn->bhp", s, c_t)
        return s, y

    s0 = (jnp.zeros((B, H, P, N), jnp.float32) if init is None
          else init.astype(jnp.float32))
    inputs = (
        xf.transpose(1, 0, 2, 3),
        dtf.transpose(1, 0, 2),
        Bf.transpose(1, 0, 2, 3),
        Cf.transpose(1, 0, 2, 3),
    )
    final, ys = lax.scan(body, s0, inputs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), final
