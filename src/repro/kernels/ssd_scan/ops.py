"""Dispatching wrapper for the SSD chunked-scan kernel."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..dispatch import resolve
from .kernel import ssd_scan as _ssd_kernel
from .ref import ssd_scan_ref


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _ssd_pallas(x, dt, A, Bm, Cm, init, chunk: int, interpret: bool):
    T = x.shape[1]
    cl = chunk
    while T % cl:
        cl //= 2
    return _ssd_kernel(x, dt, A, Bm, Cm, init, chunk=max(cl, 1),
                       interpret=interpret)


def ssd(x, dt, A, Bm, Cm, *, init=None, chunk: int = 128,
        interpret: Optional[bool] = None, use_ref: bool = False,
        backend: Optional[str] = None):
    """x (B,T,H,P), dt (B,T,H), A (H,), Bm/Cm (B,T,N) shared or
    (B,T,G,N) per-group, ``init`` (B,H,P,N) optional initial SSM state
    -> (y, final_state)."""
    choice = resolve("ssd_scan", backend or ("ref" if use_ref else "pallas"),
                     interpret=interpret)
    if not choice.use_pallas:
        return ssd_scan_ref(x, dt, A, Bm, Cm, init)
    if Bm.ndim == 3:  # shared across heads == one group
        Bm = Bm[:, :, None]
        Cm = Cm[:, :, None]
    B, T, H, P = x.shape
    N = Bm.shape[-1]
    if init is None:
        init = jnp.zeros((B, H, P, N), jnp.float32)
    return _ssd_pallas(x, dt, A, Bm, Cm, init, chunk, choice.interpret)
