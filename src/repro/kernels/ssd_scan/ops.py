"""Jit'd wrapper for the SSD chunked-scan kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import ssd_scan as _ssd_kernel
from .ref import ssd_scan_ref


@functools.partial(jax.jit, static_argnames=("chunk", "interpret", "use_ref"))
def ssd(x, dt, A, Bm, Cm, *, chunk: int = 128, interpret: bool = True,
        use_ref: bool = False):
    """x (B,T,H,P), dt (B,T,H), A (H,), Bm/Cm (B,T,N) -> (y, final_state)."""
    if use_ref:
        return ssd_scan_ref(x, dt, A, Bm, Cm)
    T = x.shape[1]
    cl = chunk
    while T % cl:
        cl //= 2
    return _ssd_kernel(x, dt, A, Bm, Cm, chunk=max(cl, 1), interpret=interpret)
