"""Pallas TPU kernel: Mamba2 SSD chunked scan (arXiv:2405.21060, Sec 6).

Grid (B, H, n_chunks); the chunk dimension is sequential ("arbitrary")
and the inter-chunk SSM state (P, N) lives in VMEM scratch, carried
across chunk iterations — the TPU-native shape of the SSD recurrence:
intra-chunk duality runs on the MXU as (cl x cl) matmuls, the state
update is a rank-cl outer-product accumulation.

Inputs:
  x    (B, T, H, P)     dt (B, T, H)   post-softplus
  A    (H,) negative    Bm/Cm (B, T, G, N) per-group (head h uses group
                        h // (H//G); G=1 reproduces the shared layout)
  init (B, H, P, N)     initial SSM state (zeros for a fresh sequence)
Outputs: y (B, T, H, P), final state (B, H, P, N).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..dispatch import compiler_params


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, init_ref, y_ref, fin_ref,
            state_ref, *, n_chunks: int, out_dtype):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = init_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, :, 0].astype(jnp.float32)  # (cl, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (cl,)
    a = a_ref[0].astype(jnp.float32)  # scalar
    bm = b_ref[0, :, 0].astype(jnp.float32)  # (cl, N)
    cm = c_ref[0, :, 0].astype(jnp.float32)  # (cl, N)

    da = dt * a  # (cl,)
    ca = jnp.cumsum(da)  # (cl,)

    # intra-chunk (dual) term: scores[i,j] = (C_i . B_j) * exp(ca_i - ca_j) * dt_j, i >= j
    cl = x.shape[0]
    seg = ca[:, None] - ca[None, :]
    tri = jnp.tril(jnp.ones((cl, cl), jnp.float32))
    lmat = jnp.exp(seg) * tri
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (cl, cl)
    scores = cb * lmat * dt[None, :]
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (cl, P)

    # inter-chunk contribution from the carried state
    state = state_ref[...]  # (P, N)
    y += jnp.exp(ca)[:, None] * jax.lax.dot_general(
        cm, state, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    # state update: S <- exp(sum dA) * S + sum_j exp(ca_last - ca_j) dt_j x_j B_j^T
    decay_out = jnp.exp(ca[-1] - ca) * dt  # (cl,)
    outer = jax.lax.dot_general(x * decay_out[:, None], bm,
                                (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (P, N)
    state_ref[...] = jnp.exp(ca[-1]) * state + outer

    y_ref[0, :, 0] = y.astype(out_dtype)

    @pl.when(ci == n_chunks - 1)
    def _fin():
        fin_ref[0, 0] = state_ref[...]


def ssd_scan(
    x: jax.Array,  # (B, T, H, P)
    dt: jax.Array,  # (B, T, H)
    A: jax.Array,  # (H,)
    Bm: jax.Array,  # (B, T, G, N)
    Cm: jax.Array,  # (B, T, G, N)
    init: jax.Array,  # (B, H, P, N) initial state
    *,
    chunk: int = 128,
    interpret: bool = False,
):
    B, T, H, P = x.shape
    G, N = Bm.shape[-2:]
    assert Bm.shape == (B, T, G, N) and Cm.shape == Bm.shape, (Bm.shape, Cm.shape)
    assert H % G == 0, (H, G)
    hpg = H // G
    assert init.shape == (B, H, P, N), init.shape
    cl = min(chunk, T)
    assert T % cl == 0, (T, cl)
    n_chunks = T // cl
    grid = (B, H, n_chunks)
    out_dtype = x.dtype
    kernel = functools.partial(_kernel, n_chunks=n_chunks, out_dtype=out_dtype)
    y, fin = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, cl, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, cl, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, cl, 1, N), lambda b, h, c: (b, c, h // hpg, 0)),
            pl.BlockSpec((1, cl, 1, N), lambda b, h, c: (b, c, h // hpg, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, cl, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, H, P), out_dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        **compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(x, dt, A, Bm, Cm, init.astype(jnp.float32))
    return y, fin
