"""Path-based parameter sharding rules.

Tensor parallel ("model" axis): attention heads, MLP hidden, experts,
vocab. Optional FSDP: additionally shard a large unsharded weight dim
over the data axes (enabled automatically when the per-chip TP-only
weight footprint would exceed ``FSDP_THRESHOLD_BYTES``).

All specs are pruned for divisibility against the actual mesh, so the
same rules serve every (arch x mesh) combination.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig
from ..models.runtime import Runtime

FSDP_THRESHOLD_BYTES = 11e9  # ~11 GB of 16 GB v5e HBM left for weights


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


# rules: (suffix match, spec for the TRAILING dims of the leaf)
_RULES: Tuple[Tuple[str, Tuple], ...] = (
    ("embed", (("model",), None)),
    ("lm_head", (None, ("model",))),
    # attention
    ("mixer/wq", (None, ("model",))),
    ("mixer/wk", (None, ("model",))),
    ("mixer/wv", (None, ("model",))),
    ("mixer/wo", (("model",), None)),
    # mamba2
    ("mixer/in_proj", (None, ("model",))),
    ("mixer/conv_w", (None, ("model",))),
    ("mixer/conv_b", (("model",),)),
    ("mixer/out_proj", (("model",), None)),
    ("mixer/norm_w", (("model",),)),
    # MoE experts: shard the expert dim (expert parallelism)
    ("ffn/router", (None, None)),
    ("ffn/wg", (("model",), None, None)),
    ("ffn/wu", (("model",), None, None)),
    ("ffn/wd", (("model",), None, None)),
    # dense / shared-expert MLP
    ("ffn/shared/wg", (None, ("model",))),
    ("ffn/shared/wu", (None, ("model",))),
    ("ffn/shared/wd", (("model",), None)),
    ("shared/ffn/wg", (None, ("model",))),
    ("shared/ffn/wu", (None, ("model",))),
    ("shared/ffn/wd", (("model",), None)),
    # LoRA adapters: expert dim over "model" (match the base experts)
    ("/a", (("model",), None, None)),
    ("/b", (("model",), None, None)),
)

_DENSE_FFN = (
    ("ffn/wg", (None, ("model",))),
    ("ffn/wu", (None, ("model",))),
    ("ffn/wd", (("model",), None)),
)


def leaf_spec(path_str: str, leaf, *, fsdp: bool, data_axes: Tuple[str, ...],
              profile: str = "tp") -> P:
    ndim = getattr(leaf, "ndim", len(getattr(leaf, "shape", ())))
    if profile == "pure_fsdp":
        # no TP rules: shard the first trailing weight dim over ALL axes
        if ndim < 1:
            return P()
        entries = [None] * ndim
        start = 1 if ndim >= 3 else 0  # skip the scan-repeat dim
        entries[start] = tuple(data_axes) if len(data_axes) > 1 else (
            data_axes[0] if data_axes else None
        )
        return P(*entries)
    rules = _RULES
    # dense-MLP wg/wu/wd (3D incl. repeat dim) vs MoE expert stacks (4D)
    if "/ffn/w" in path_str and "shared" not in path_str and ndim <= 3:
        rules = _DENSE_FFN + _RULES
    trailing: Optional[Tuple] = None
    for suffix, spec in rules:
        if path_str.endswith(suffix) or (suffix + "/") in path_str or suffix in path_str:
            trailing = spec
            break
    if trailing is None:
        return P()
    # left-pad with None for leading (repeat/expert) dims
    entries = [None] * (ndim - len(trailing)) + [
        (t[0] if isinstance(t, tuple) and t else t) for t in trailing
    ]
    entries = entries[:ndim]
    if fsdp and data_axes and ndim >= 2:
        # shard the first unsharded *trailing weight* dim over the data axes
        for i in range(ndim - len(trailing), ndim):
            if entries[i] is None:
                entries[i] = tuple(data_axes) if len(data_axes) > 1 else data_axes[0]
                break
    return P(*entries)


def param_pspecs(params_or_shapes, cfg: ModelConfig, rt: Runtime, *,
                 fsdp: Optional[bool] = None):
    """PartitionSpec tree for the parameter pytree (divisibility-pruned)."""
    if fsdp is None:
        fsdp = needs_fsdp(cfg, rt)
    data_axes = rt.data_axes

    def per_leaf(path, leaf):
        spec = leaf_spec(_path_str(path), leaf, fsdp=fsdp, data_axes=data_axes,
                         profile=rt.profile)
        return rt.prune_spec(leaf.shape, spec)

    return jax.tree_util.tree_map_with_path(per_leaf, params_or_shapes)


def needs_fsdp(cfg: ModelConfig, rt: Runtime) -> bool:
    if not rt.sharded:
        return False
    ms = rt.axis_size("model")
    bytes_tp = cfg.param_counts()["total"] * 2 / ms  # bf16
    return bytes_tp > FSDP_THRESHOLD_BYTES


def param_shardings(params_or_shapes, cfg: ModelConfig, rt: Runtime, *,
                    fsdp: Optional[bool] = None):
    specs = param_pspecs(params_or_shapes, cfg, rt, fsdp=fsdp)
    return jax.tree.map(lambda s: NamedSharding(rt.mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------


def batch_pspecs(batch, rt: Runtime):
    """Shard the leading (batch) dim of every input leaf over data axes."""
    entry = rt.batch_spec_entry()

    def per_leaf(leaf):
        if leaf.ndim == 0:
            return P()
        return rt.prune_spec(leaf.shape, P(entry))

    return jax.tree.map(per_leaf, batch)


def cache_pspecs(cache, rt: Runtime):
    """KV/SSM cache: batch over data axes, kv-heads / inner-dim over model."""
    from ..models.attention import KVCache
    from ..models.mamba2 import MambaState

    entry = rt.batch_spec_entry()

    ms = rt.axis_size("model")
    if rt.profile == "pure_fsdp":
        def handle_fsdp(node):
            if isinstance(node, KVCache):
                kv = rt.prune_spec(node.k.shape, P(None, entry, None, None, None))
                sp = rt.prune_spec(node.slot_pos.shape, P(None, entry, None))
                return KVCache(k=kv, v=kv, slot_pos=sp)
            if isinstance(node, MambaState):
                return MambaState(
                    conv=rt.prune_spec(node.conv.shape, P(None, entry)),
                    ssm=rt.prune_spec(node.ssm.shape, P(None, entry)),
                )
            return P()

        return jax.tree.map(handle_fsdp, cache,
                            is_leaf=lambda n: isinstance(n, (KVCache, MambaState)))

    def handle(node):
        if isinstance(node, KVCache):
            # prefer kv-head sharding; fall back to *sequence* (slot) dim
            # when the arch has fewer kv heads than model shards (GQA kv=8
            # on a 16-way axis). Slot sharding keeps attention local up to
            # a small score all-reduce (flash-decode-style); head_dim
            # sharding makes GSPMD all-gather the whole cache.
            if node.k.shape[3] % ms == 0:
                spec = P(None, entry, None, "model", None)
            else:
                spec = P(None, entry, "model", None, None)
            kv = rt.prune_spec(node.k.shape, spec)
            sp = rt.prune_spec(node.slot_pos.shape, P(None, entry, None))
            return KVCache(k=kv, v=kv, slot_pos=sp)
        if isinstance(node, MambaState):
            return MambaState(
                conv=rt.prune_spec(node.conv.shape, P(None, entry, None, "model")),
                ssm=rt.prune_spec(node.ssm.shape, P(None, entry, "model", None, None)),
            )
        return P()  # scalars (pos)

    return jax.tree.map(
        handle, cache, is_leaf=lambda n: isinstance(n, (KVCache, MambaState))
    )
