"""Invariant-audit watchdog: cross-checks the serving stack's books.

Every component with durable state exposes an ``audit()`` contract
returning violation strings (empty == healthy):

* ``OffloadedMoEEngine.audit()`` — slab free-list vs cache accounting,
  slot-map inverse consistency, ghost slots
* ``ModelExpertCache.audit()`` / ``LayerExpertCache.audit()`` —
  capacity, id ranges, score sanity
* ``RequestQueue.audit()`` — arrival conservation, heap order
* ``ServerMetrics.audit()`` — counter sanity
* ``BatchState.audit()`` — slot liveness / duplicate rids

The :class:`Watchdog` runs them all plus the cross-component queue-
conservation law

    arrived + offered_base == finished + shed + expired + pending + in-flight

on a cadence (every N steps / waves) and after every restore. Engine
findings tagged ``drift`` (dict-impl stale residents) are self-healed
via ``resync_slabs()`` and re-checked; anything that survives is
published to ``repro.obs`` as ``audit_violations_total`` and — in
strict mode — raised as :class:`AuditError` (fail fast beats serving
from corrupt state).
"""
from __future__ import annotations

from typing import List, Optional


class AuditError(RuntimeError):
    """At least one integrity invariant does not hold."""

    def __init__(self, violations: List[str]):
        self.violations = list(violations)
        super().__init__(
            f"{len(self.violations)} invariant violation(s): "
            + "; ".join(self.violations))


class Watchdog:
    """Periodic integrity auditor over one server's components.

    Components are optional — pass whichever exist on this serving
    path. ``offered_base`` offsets the conservation law by the requests
    already resolved before a restore (the rebuilt queue never saw
    them). ``healed_total`` counts slab-drift resyncs.
    """

    def __init__(self, *, queue=None, metrics=None, engine=None, batch=None,
                 offered_base: int = 0, strict: bool = True, registry=None):
        self.queue = queue
        self.metrics = metrics
        self.engine = engine
        self.batch = batch
        self.offered_base = int(offered_base)
        self.strict = strict
        if registry is None:
            from ..obs.registry import REGISTRY as registry
        self.registry = registry
        self.runs = 0
        self.healed_total = 0
        # materialize the series at zero so a green run still exports
        # audit_violations_total == 0 (CI asserts on the sample)
        for comp in ("queue", "metrics", "engine", "batch", "conservation"):
            self._violations_counter(comp).inc(0)
        registry.counter("audit_runs_total", "watchdog audit passes").inc(0)

    def _violations_counter(self, component: str):
        return self.registry.counter(
            "audit_violations_total",
            "invariant violations found by the recovery watchdog",
            component=component)

    # -- the audit pass --------------------------------------------------
    def check(self, in_flight: int = 0) -> List[str]:
        """Run every component audit + the conservation law. Returns the
        surviving violations (after drift self-heal); raises
        :class:`AuditError` in strict mode when any remain."""
        self.runs += 1
        self.registry.counter("audit_runs_total",
                              "watchdog audit passes").inc()
        violations: List[str] = []

        if self.engine is not None:
            findings = self.engine.audit()
            if any(sev == "drift" for sev, _ in findings):
                # recoverable bookkeeping drift: resync the slabs to the
                # cache manager's view, then demand a clean re-audit
                self.healed_total += self.engine.resync_slabs()
                findings = self.engine.audit()
            for sev, msg in findings:
                violations.append(f"engine[{sev}]: {msg}")
                self._violations_counter("engine").inc()

        for comp, obj in (("queue", self.queue), ("metrics", self.metrics),
                          ("batch", self.batch)):
            if obj is None:
                continue
            for msg in obj.audit():
                violations.append(f"{comp}: {msg}")
                self._violations_counter(comp).inc()

        cons = self._conservation(in_flight)
        if cons is not None:
            violations.append(cons)
            self._violations_counter("conservation").inc()

        if violations and self.strict:
            raise AuditError(violations)
        return violations

    def _conservation(self, in_flight: int) -> Optional[str]:
        """Queue-conservation law across queue + metrics + batch."""
        if self.queue is None or self.metrics is None:
            return None
        mt = self.metrics
        arrived = self.queue.arrived_total + self.offered_base
        resolved = (mt.requests_finished + mt.requests_shed
                    + mt.requests_expired)
        accounted = resolved + len(self.queue) + int(in_flight)
        if arrived != accounted:
            return (f"conservation: arrived {arrived} (incl. offered_base="
                    f"{self.offered_base}) != finished {mt.requests_finished}"
                    f" + shed {mt.requests_shed} + expired "
                    f"{mt.requests_expired} + pending {len(self.queue)}"
                    f" + in-flight {int(in_flight)} = {accounted}")
        return None
