"""Shared serialization primitives for durable state.

One helper set serves every on-disk format in the repo: the msgpack
model checkpoints (``training/checkpoint.py``), the server snapshots
(``recovery/checkpoint.py``), and the JSONL request journal
(``recovery/journal.py``). Arrays round-trip through a tiny
self-describing record — ``{"dtype", "shape", "data"|"b64"}`` — with raw
bytes for binary containers (msgpack) and base64 text for line-oriented
JSON, and every durable write goes through :func:`atomic_write_bytes`
(temp file + ``os.replace``) so a crash mid-write can never leave a
torn file where a reader expects a complete one.
"""
from __future__ import annotations

import base64
import os
from pathlib import Path
from typing import Optional

import numpy as np


def array_record(arr, *, binary: bool = True) -> dict:
    """Encode an array as a self-describing dict. ``binary=True`` keeps
    raw bytes (msgpack containers); ``binary=False`` base64-encodes for
    JSON/JSONL lines. Works for any dtype numpy can describe by name,
    including ``bfloat16`` via ml_dtypes."""
    a = np.asarray(arr)
    rec = {"dtype": str(a.dtype), "shape": list(a.shape)}
    # NB: ascontiguousarray AFTER recording the shape — it promotes 0-d
    # scalars to shape (1,)
    a = np.ascontiguousarray(a)
    if binary:
        rec["data"] = a.tobytes()
    else:
        rec["b64"] = base64.b64encode(a.tobytes()).decode("ascii")
    return rec


def record_array(rec: Optional[dict]) -> Optional[np.ndarray]:
    """Decode an :func:`array_record` (either encoding). None passes
    through so optional fields round-trip without special cases."""
    if rec is None:
        return None
    raw = rec["data"] if "data" in rec else base64.b64decode(rec["b64"])
    arr = np.frombuffer(raw, dtype=np.dtype(rec["dtype"]))
    return arr.reshape(rec["shape"]).copy()


def atomic_write_bytes(path, data: bytes) -> None:
    """Durably replace ``path`` with ``data``: write a sibling temp
    file, fsync it, then ``os.replace`` — readers only ever observe the
    old complete file or the new complete file."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_name(path.name + ".tmp")
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
