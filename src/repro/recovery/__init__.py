"""Crash-safe serving: journal, checkpoint/restore, integrity watchdog.

Layers:
  serial.py     — shared array/bytes serialization + atomic file writes
                  (also used by ``training/checkpoint.py``)
  journal.py    — append-only JSONL write-ahead request journal with
                  atomic-rename rotation and crash-tolerant replay
  checkpoint.py — periodic server snapshots (queue, in-flight progress,
                  sampler seed, ServerMetrics, per-layer cache state)
                  and the restore path that rebuilds a resumable state
  audit.py      — invariant-audit watchdog cross-checking engine / cache
                  / queue / metrics accounting, publishing
                  ``audit_violations_total`` and self-healing slab drift
"""
from .audit import AuditError, Watchdog
from .checkpoint import (
    load_server_checkpoint,
    save_server_checkpoint,
)
from .journal import (
    JOURNAL_ENV_VAR,
    RecoveredState,
    RequestJournal,
    journal_dir_from_env,
    recover,
)
from .serial import array_record, atomic_write_bytes, record_array

__all__ = [
    "AuditError",
    "Watchdog",
    "RequestJournal",
    "RecoveredState",
    "recover",
    "journal_dir_from_env",
    "JOURNAL_ENV_VAR",
    "save_server_checkpoint",
    "load_server_checkpoint",
    "array_record",
    "record_array",
    "atomic_write_bytes",
]
