"""Server snapshots: the periodic half of the crash-recovery story.

A checkpoint captures everything a server needs to resume mid-run:
the queue's pending requests, in-flight progress (per-request emitted-
token watermarks), the sampler seed, the full ``ServerMetrics`` state,
the results produced so far, and — the MELINOE-specific part — each
layer's expert-cache resident set + policy scores so ``revive()`` can
warm-load the slab instead of cold-starting. Payloads are msgpack via
the shared ``serial`` helpers and land atomically, so the journal can
always trust the last checkpoint it references.
"""
from __future__ import annotations

from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import msgpack
import numpy as np

from ..serving.metrics import ServerMetrics
from ..serving.request import ServeRequest, ServeResult
from .serial import array_record, atomic_write_bytes, record_array

CKPT_VERSION = 1


# ---------------------------------------------------------------------------
# request / result records (shared with the journal's JSONL events)
# ---------------------------------------------------------------------------


def request_record(req: ServeRequest, *, binary: bool = False,
                   emitted: Optional[Sequence[int]] = None) -> Dict:
    """Full request spec as a plain dict. ``emitted`` records the
    pre-crash watermark (in-flight checkpoints); a request resumed from
    an earlier crash folds its ``resumed`` prefix in, so the watermark
    is always absolute."""
    pre = [] if req.resumed is None else [int(t) for t in req.resumed]
    return {
        "rid": int(req.rid),
        "prompt": array_record(req.prompt, binary=binary),
        "max_new_tokens": int(req.max_new_tokens),
        "temperature": float(req.temperature),
        "stop_tokens": [int(t) for t in req.stop_tokens],
        "arrival_time": float(req.arrival_time),
        "cluster": None if req.cluster is None else int(req.cluster),
        "slo": None if req.slo is None else float(req.slo),
        "quality": float(req.quality),
        "expert_scores": (None if req.expert_scores is None
                          else array_record(req.expert_scores, binary=binary)),
        "emitted": pre + [int(t) for t in (emitted or [])],
    }


def record_request(rec: Dict) -> ServeRequest:
    """Rebuild a :class:`ServeRequest`; a non-empty ``emitted``
    watermark becomes the ``resumed`` prefix."""
    emitted = rec.get("emitted") or []
    return ServeRequest(
        rid=int(rec["rid"]),
        prompt=record_array(rec["prompt"]).astype(np.int32),
        max_new_tokens=int(rec["max_new_tokens"]),
        temperature=float(rec["temperature"]),
        stop_tokens=tuple(int(t) for t in rec["stop_tokens"]),
        arrival_time=float(rec["arrival_time"]),
        cluster=rec.get("cluster"),
        slo=rec.get("slo"),
        quality=float(rec.get("quality", 1.0)),
        expert_scores=record_array(rec.get("expert_scores")),
        resumed=(np.asarray(emitted, np.int32) if emitted else None),
    )


def result_record(res: ServeResult) -> Dict:
    return {
        "rid": int(res.rid),
        "tokens": [int(t) for t in res.tokens],
        "finish_reason": res.finish_reason,
        "arrival_time": float(res.arrival_time),
        "start_time": float(res.start_time),
        "finish_time": float(res.finish_time),
        "decode_steps": int(res.decode_steps),
        "degraded": bool(res.degraded),
    }


def record_result(rec: Dict) -> ServeResult:
    return ServeResult(
        rid=int(rec["rid"]),
        tokens=np.asarray(rec["tokens"], np.int32),
        finish_reason=rec["finish_reason"],
        arrival_time=float(rec["arrival_time"]),
        start_time=float(rec["start_time"]),
        finish_time=float(rec["finish_time"]),
        decode_steps=int(rec.get("decode_steps", 0)),
        degraded=bool(rec.get("degraded", False)),
    )


# ---------------------------------------------------------------------------
# engine cache state (array fields -> records)
# ---------------------------------------------------------------------------


def _enc_cache_layer(st: Dict) -> Dict:
    return {**st, "counts": array_record(st["counts"]),
            "last_used": array_record(st["last_used"])}


def _dec_cache_layer(st: Dict) -> Dict:
    return {**st, "counts": record_array(st["counts"]),
            "last_used": record_array(st["last_used"])}


# ---------------------------------------------------------------------------
# save / load
# ---------------------------------------------------------------------------


def save_server_checkpoint(
    path,
    *,
    kind: str,
    step: int,
    now: float,
    seed: int,
    policy: str,
    pending: Sequence[ServeRequest],
    inflight: Sequence[Tuple[ServeRequest, Sequence[int]]],
    results: Sequence[ServeResult],
    metrics: ServerMetrics,
    engine: Optional[Dict] = None,
) -> None:
    """Atomically write one server snapshot. ``inflight`` pairs each
    in-service request with its emitted-token watermark; ``engine`` is
    ``{"cache": OffloadedMoEEngine.cache_state(), "metrics":
    EngineMetrics.state()}`` on the offloaded path."""
    assert kind in ("continuous", "wave"), kind
    payload = {
        "version": CKPT_VERSION,
        "kind": kind,
        "step": int(step),
        "now": float(now),
        "seed": int(seed),
        "policy": policy,
        "pending": [request_record(r, binary=True) for r in pending],
        "inflight": [request_record(r, binary=True, emitted=em)
                     for r, em in inflight],
        "results": [result_record(r) for r in results],
        "metrics": metrics.to_state(),
        "engine": (None if engine is None else {
            "cache": [_enc_cache_layer(st) for st in engine["cache"]],
            "metrics": engine["metrics"],
        }),
    }
    atomic_write_bytes(path, msgpack.packb(payload, use_bin_type=True))


def load_server_checkpoint(path) -> Dict:
    """Decode a snapshot back to plain python (cache-layer arrays
    restored to numpy; request/result records left as dicts for the
    journal replay to merge with post-checkpoint events)."""
    payload = msgpack.unpackb(Path(path).read_bytes(), raw=False)
    assert payload["version"] == CKPT_VERSION, payload["version"]
    if payload.get("engine") is not None:
        payload["engine"] = {
            "cache": [_dec_cache_layer(st)
                      for st in payload["engine"]["cache"]],
            "metrics": payload["engine"]["metrics"],
        }
    return payload
