"""Write-ahead request journal: the append-only half of crash recovery.

Every server event lands as one JSONL line, flushed per append so the
journal is current up to the instant of a crash:

  ``base``    first line of a fresh segment, pointing at the checkpoint
              that summarizes everything before it
  ``ckpt``    checkpoint marker appended just before rotation (also the
              recovery anchor when a crash interrupts rotation itself)
  ``arrival`` full request spec (prompt base64-encoded via the shared
              ``serial`` records)
  ``admit``   request left the queue for a slot / wave
  ``wm``      per-request emitted-token watermark (the tokens produced
              by one decode step — re-prefill target after a crash)
  ``retire``  final tokens + finish bookkeeping for one request
  ``shed``    admission control turned the request away

Rotation is atomic-rename: on checkpoint the active segment gains a
``ckpt`` marker, is renamed to ``journal-NNNN.jsonl``, and a fresh
``journal.jsonl`` opens with a ``base`` record — so recovery only ever
replays the active segment: last anchored checkpoint + events after it.
A torn tail line (crash mid-write) is detected and skipped.

``recover()`` folds checkpoint + tail back into a
:class:`RecoveredState`: finished results, restored metrics, engine
cache state for warm revival, and the still-live requests — in-flight
ones carrying their watermark as ``ServeRequest.resumed`` so greedy
decode continues token-identically to an uninterrupted run.
"""
from __future__ import annotations

import json
import os
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Set

import numpy as np

from ..serving.metrics import ServerMetrics
from ..serving.queue import RequestQueue
from ..serving.request import ServeRequest, ServeResult
from .checkpoint import (
    load_server_checkpoint,
    record_request,
    record_result,
    request_record,
)

JOURNAL_ENV_VAR = "REPRO_JOURNAL"
_SEGMENT_RE = re.compile(r"journal-(\d+)\.jsonl$")


def journal_dir_from_env() -> Optional[str]:
    """Default journal directory (``REPRO_JOURNAL``), if configured."""
    return os.environ.get(JOURNAL_ENV_VAR) or None


class RequestJournal:
    """Append-only JSONL event log with atomic-rename rotation."""

    def __init__(self, directory, *, seen: Optional[Set[int]] = None,
                 retain_segments: Optional[int] = 2):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.path = self.dir / "journal.jsonl"
        # rids whose arrival is already durable (survives reopen-on-
        # restore: the recovered state hands its rid set back in)
        self._seen: Set[int] = set(seen or ())
        # rotated segments to keep beyond the active one (None = keep
        # everything). Recovery only ever replays the active segment +
        # its anchored checkpoint, so older segments are forensic
        # history; without pruning a long-lived worker grows one
        # segment + one checkpoint per rotation, forever.
        self.retain_segments = retain_segments
        segs = [int(m.group(1)) for p in self.dir.iterdir()
                if (m := _SEGMENT_RE.match(p.name))]
        self._seq = max(segs, default=-1) + 1
        self._fh = open(self.path, "a", encoding="utf-8")

    # -- low-level append ------------------------------------------------
    def append(self, ev: str, **fields) -> None:
        line = json.dumps({"ev": ev, **fields}, separators=(",", ":"))
        self._fh.write(line + "\n")
        self._fh.flush()

    # -- event helpers ---------------------------------------------------
    def arrival(self, req: ServeRequest) -> None:
        """Journal a request spec once (idempotent per rid)."""
        if req.rid in self._seen:
            return
        self._seen.add(req.rid)
        self.append("arrival", **request_record(req, binary=False))

    def admit(self, rid: int, now: float) -> None:
        self.append("admit", rid=int(rid), now=float(now))

    def watermark(self, toks: Dict[int, List[int]], now: float) -> None:
        """One decode step's newly emitted tokens, per rid."""
        if toks:
            self.append("wm", toks={str(r): [int(t) for t in ts]
                                    for r, ts in toks.items()},
                        now=float(now))

    def retire(self, res: ServeResult, *, plen: int, attained: bool,
               ttft: Optional[float] = None,
               itl: Optional[float] = None) -> None:
        self.append(
            "retire", rid=int(res.rid),
            tokens=[int(t) for t in res.tokens],
            reason=res.finish_reason, arrival=float(res.arrival_time),
            start=float(res.start_time), finish=float(res.finish_time),
            decode_steps=int(res.decode_steps), degraded=bool(res.degraded),
            attained=bool(attained), plen=int(plen),
            ttft=None if ttft is None else float(ttft),
            itl=None if itl is None else float(itl))

    def shed(self, req: ServeRequest, *, expired: bool, now: float) -> None:
        self.append("shed", rid=int(req.rid), expired=bool(expired),
                    arrival=float(req.arrival_time), now=float(now))

    # -- checkpoint + rotation -------------------------------------------
    def checkpoint_path(self, step: int) -> Path:
        return self.dir / f"ckpt-{int(step):08d}.msgpack"

    def rotate(self, ckpt_path, step: int, now: float) -> None:
        """Anchor the just-written checkpoint and start a fresh segment.
        The marker goes into the old segment BEFORE the rename so a
        crash at any point leaves a recoverable anchor somewhere."""
        self.append("ckpt", ckpt=str(ckpt_path), step=int(step),
                    now=float(now))
        self._fh.flush()
        os.fsync(self._fh.fileno())
        self._fh.close()
        os.replace(self.path, self.dir / f"journal-{self._seq:04d}.jsonl")
        self._seq += 1
        self._fh = open(self.path, "a", encoding="utf-8")
        self.append("base", ckpt=str(ckpt_path), step=int(step),
                    now=float(now))
        self._prune()

    def _prune(self) -> None:
        """Segment retention: drop rotated segments beyond the newest
        ``retain_segments``, then any checkpoint file no retained
        segment (or the active one) anchors. Every retained segment
        still starts with a ``base`` record pointing at a live
        checkpoint, so recovery after pruning is unchanged."""
        if self.retain_segments is None or self.retain_segments < 0:
            return
        segs = sorted(
            (p for p in self.dir.iterdir() if _SEGMENT_RE.match(p.name)),
            key=lambda p: int(_SEGMENT_RE.match(p.name).group(1)))
        cut = len(segs) - self.retain_segments
        if cut <= 0:
            return
        drop, keep = segs[:cut], segs[cut:]
        referenced: Set[str] = set()
        for seg in [*keep, self.path]:
            try:
                with open(seg, "r", encoding="utf-8") as f:
                    for line in f:
                        try:
                            ev = json.loads(line)
                        except json.JSONDecodeError:
                            continue
                        if ev.get("ev") in ("base", "ckpt"):
                            referenced.add(Path(ev["ckpt"]).name)
            except OSError:
                continue
        for p in drop:
            p.unlink(missing_ok=True)
        for p in self.dir.glob("ckpt-*.msgpack"):
            if p.name not in referenced:
                p.unlink(missing_ok=True)

    def close(self) -> None:
        if not self._fh.closed:
            self._fh.flush()
            self._fh.close()


# ---------------------------------------------------------------------------
# recovery: checkpoint + journal tail -> resumable state
# ---------------------------------------------------------------------------


@dataclass
class RecoveredState:
    """Everything a server needs to resume after a crash."""

    kind: str = "continuous"  # which server wrote the journal
    now: float = 0.0
    step: int = 0
    seed: int = 0
    policy: str = "fcfs"
    results: List[ServeResult] = field(default_factory=list)
    metrics: ServerMetrics = field(default_factory=ServerMetrics)
    # still-live requests (pending + former in-flight, watermarks set)
    pending: List[ServeRequest] = field(default_factory=list)
    # {"cache": [...], "metrics": {...}} on the offloaded path
    engine: Optional[Dict] = None
    seen_rids: Set[int] = field(default_factory=set)
    # requests already resolved before the restore — the watchdog's
    # conservation offset (the rebuilt queue never sees them)
    offered_base: int = 0

    def build_queue(self, max_pending: Optional[int] = None) -> RequestQueue:
        return RequestQueue(self.pending, max_pending=max_pending)


def _read_events(path: Path) -> List[Dict]:
    """Parse a JSONL segment, skipping torn/corrupt lines (a crash can
    truncate the tail mid-write)."""
    events = []
    with open(path, "r", encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                events.append(json.loads(line))
            except json.JSONDecodeError:
                continue  # torn write; everything before it is intact
    return events


def _active_segment(directory: Path) -> Optional[Path]:
    active = directory / "journal.jsonl"
    if active.exists():
        return active
    # crash between rotation's rename and the new segment's open: the
    # freshest rotated segment ends in a ckpt marker and anchors recovery
    segs = sorted(
        (p for p in directory.iterdir() if _SEGMENT_RE.match(p.name)),
        key=lambda p: int(_SEGMENT_RE.match(p.name).group(1)))
    return segs[-1] if segs else None


def recover(directory) -> Optional[RecoveredState]:
    """Rebuild a :class:`RecoveredState` from a journal directory, or
    None when there is nothing to recover from."""
    directory = Path(directory)
    seg = _active_segment(directory) if directory.exists() else None
    if seg is None:
        return None
    events = _read_events(seg)

    # find the last anchored checkpoint that actually loads
    ckpt = None
    start = 0
    for i in range(len(events) - 1, -1, -1):
        ev = events[i]
        if ev.get("ev") in ("base", "ckpt"):
            try:
                ckpt = load_server_checkpoint(ev["ckpt"])
            except (OSError, ValueError, KeyError, AssertionError):
                continue  # anchor's file lost/torn; try an earlier one
            start = i + 1
            break

    st = RecoveredState()
    requests: Dict[int, Dict] = {}
    emitted: Dict[int, List[int]] = {}
    done: Set[int] = set()

    if ckpt is not None:
        st.kind = ckpt["kind"]
        st.now = ckpt["now"]
        st.step = ckpt["step"]
        st.seed = ckpt["seed"]
        st.policy = ckpt["policy"]
        st.metrics = ServerMetrics.from_state(ckpt["metrics"])
        st.engine = ckpt.get("engine")
        for rec in ckpt["results"]:
            st.results.append(record_result(rec))
            done.add(int(rec["rid"]))
        for rec in ckpt["pending"]:
            requests[int(rec["rid"])] = rec
            emitted[int(rec["rid"])] = list(rec.get("emitted") or [])
        for rec in ckpt["inflight"]:
            requests[int(rec["rid"])] = rec
            emitted[int(rec["rid"])] = list(rec.get("emitted") or [])

    mt = st.metrics
    for ev in events[start:]:
        kind = ev.get("ev")
        if kind == "arrival":
            rid = int(ev["rid"])
            if rid not in requests and rid not in done:
                requests[rid] = ev
                emitted[rid] = list(ev.get("emitted") or [])
        elif kind == "wm":
            for rid_s, toks in ev["toks"].items():
                rid = int(rid_s)
                emitted.setdefault(rid, []).extend(int(t) for t in toks)
                mt.generated_tokens += len(toks)
        elif kind == "retire":
            rid = int(ev["rid"])
            done.add(rid)
            requests.pop(rid, None)
            emitted.pop(rid, None)
            res = ServeResult(
                rid=rid, tokens=np.asarray(ev["tokens"], np.int32),
                finish_reason=ev["reason"], arrival_time=ev["arrival"],
                start_time=ev["start"], finish_time=ev["finish"],
                decode_steps=int(ev.get("decode_steps", 0)),
                degraded=bool(ev.get("degraded", False)))
            st.results.append(res)
            mt.observe_finish(res.latency, ttft=ev.get("ttft"),
                              itl=ev.get("itl"))
            if ev["reason"] == "deadline":
                mt.deadline_retired += 1
            elif ev.get("attained", True):
                mt.slo_attained += 1
            if ev.get("degraded"):
                mt.degraded_requests += 1
            if st.kind == "wave":
                # the wave path counts these at retire (generated
                # tokens were already replayed from the wm event)
                mt.decode_steps += int(ev.get("decode_steps", 0))
                mt.prefill_tokens += int(ev.get("plen", 0))
            st.now = max(st.now, ev["finish"])
        elif kind == "shed":
            rid = int(ev["rid"])
            done.add(rid)
            requests.pop(rid, None)
            emitted.pop(rid, None)
            if ev.get("expired"):
                mt.requests_expired += 1
            else:
                mt.requests_shed += 1
            st.results.append(ServeResult(
                rid=rid, tokens=np.zeros(0, np.int32), finish_reason="shed",
                arrival_time=ev["arrival"], start_time=ev["now"],
                finish_time=ev["now"]))
            st.now = max(st.now, ev["now"])
        elif kind == "admit":
            st.now = max(st.now, ev.get("now", st.now))
        # base/ckpt markers inside the tail (partial rotation) were
        # already consumed by the anchor search above

    # live requests go back to the queue; watermarks that already
    # complete a request (crash between its last wm and its retire
    # line) retire here instead of re-entering service
    for rid in sorted(requests):
        rec = dict(requests[rid])
        rec["emitted"] = emitted.get(rid, [])
        req = record_request(rec)
        em = rec["emitted"]
        reason = None
        if em:
            stops = set(req.stop_tokens)
            hit = next((i for i, t in enumerate(em) if t in stops), None)
            if hit is not None:
                em = em[: hit + 1]
                reason = "stop"
            elif len(em) >= req.max_new_tokens:
                em = em[: req.max_new_tokens]
                reason = "length"
        if reason is not None:
            attained = req.deadline is None or st.now <= req.deadline
            st.results.append(ServeResult(
                rid=rid, tokens=np.asarray(em, np.int32),
                finish_reason=reason, arrival_time=req.arrival_time,
                start_time=req.arrival_time, finish_time=st.now))
            mt.observe_finish(st.now - req.arrival_time)
            if attained:
                mt.slo_attained += 1
            done.add(rid)
            continue
        st.pending.append(req)

    st.pending.sort(key=lambda r: (r.arrival_time, r.rid))
    st.seen_rids = set(requests) | done
    st.offered_base = (mt.requests_finished + mt.requests_shed
                       + mt.requests_expired)
    return st
