"""Continuous-batching serving subsystem with expert-affinity scheduling.

Layers:
  request.py    — ServeRequest / ServeResult
  queue.py      — RequestQueue + synthetic Poisson/bursty traffic
  scheduler.py  — fcfs / sjf / expert-affinity admission policies
  batch.py      — slot-based in-flight BatchState
  metrics.py    — ServerMetrics telemetry
  scorers.py    — per-request expert-preference scorers (oracle / Psi)
                  (formerly profiling.py; that name is a shim now)
  server.py     — ContinuousBatchingServer (fits path) and
                  OffloadedWaveServer (offloaded path, Eq. 3 clock)
"""
from .batch import BatchState, SlotState
from .metrics import ServerMetrics
from .queue import RequestQueue, TrafficConfig, synthesize_workload
from .scorers import (
    predictor_expert_scores,
    prefill_expert_scores,
    prompt_router_profile,
)
from .request import ServeRequest, ServeResult
from .scheduler import (
    SCHEDULERS,
    ExpertAffinityScheduler,
    FCFSScheduler,
    Scheduler,
    SJFScheduler,
    get_scheduler,
)
from .server import ContinuousBatchingServer, OffloadedWaveServer, serve_static

__all__ = [
    "BatchState",
    "SlotState",
    "ServerMetrics",
    "RequestQueue",
    "TrafficConfig",
    "synthesize_workload",
    "ServeRequest",
    "ServeResult",
    "SCHEDULERS",
    "Scheduler",
    "FCFSScheduler",
    "SJFScheduler",
    "ExpertAffinityScheduler",
    "get_scheduler",
    "ContinuousBatchingServer",
    "OffloadedWaveServer",
    "serve_static",
    "prefill_expert_scores",
    "predictor_expert_scores",
    "prompt_router_profile",
]
