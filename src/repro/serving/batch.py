"""Slot-based in-flight batch state for continuous batching.

``BatchState`` owns the request<->slot binding and per-slot generation
bookkeeping; the KV rows themselves live in the model cache, indexed by
the same slot ids. Finished sequences retire on a stop token or their
token budget, freeing the slot for the next prefilled request — nobody
is padded to the longest request in the batch.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from .request import ServeRequest, ServeResult


@dataclass
class SlotState:
    request: Optional[ServeRequest] = None
    generated: List[int] = field(default_factory=list)
    start_time: float = 0.0
    decode_steps: int = 0

    @property
    def free(self) -> bool:
        return self.request is None


class BatchState:
    def __init__(self, n_slots: int, max_len: int):
        assert n_slots >= 1 and max_len >= 2
        self.n_slots = n_slots
        self.max_len = max_len
        self.slots = [SlotState() for _ in range(n_slots)]

    # -- queries -----------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.free]

    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if not s.free]

    def active_requests(self) -> List[ServeRequest]:
        return [s.request for s in self.slots if not s.free]

    # -- transitions -------------------------------------------------------
    def occupy(self, slot: int, req: ServeRequest, now: float) -> None:
        s = self.slots[slot]
        assert s.free, f"slot {slot} already bound to rid {s.request.rid}"
        assert all(
            t.free or t.request.rid != req.rid for t in self.slots
        ), f"rid {req.rid} already placed"
        assert req.prompt_len + req.max_new_tokens <= self.max_len, (
            f"rid {req.rid}: {req.prompt_len}+{req.max_new_tokens} tokens "
            f"exceed the {self.max_len}-slot KV budget"
        )
        # a resumed request starts with its pre-crash watermark already
        # generated, so the budget check in append_token counts from the
        # uninterrupted run's position
        pre = [] if req.resumed is None else [int(t) for t in req.resumed]
        assert len(pre) < req.max_new_tokens, (
            f"rid {req.rid}: resumed watermark {len(pre)} >= budget "
            f"{req.max_new_tokens} — should have been retired at replay"
        )
        self.slots[slot] = SlotState(request=req, start_time=now, generated=pre)

    def append_token(self, slot: int, token: int) -> Optional[str]:
        """Record one generated token; returns the finish reason if the
        sequence is now complete ("stop" | "length"), else None."""
        s = self.slots[slot]
        assert not s.free
        s.generated.append(int(token))
        if token in s.request.stop_tokens:
            return "stop"
        if len(s.generated) >= s.request.max_new_tokens:
            return "length"
        return None

    def audit(self) -> List[str]:
        """Slot-liveness check (watchdog contract): rid uniqueness and
        per-slot token budgets. Returns violation strings, empty when
        healthy."""
        v = []
        rids = [s.request.rid for s in self.slots if not s.free]
        if len(rids) != len(set(rids)):
            v.append(f"duplicate rid across slots: {sorted(rids)}")
        for i, s in enumerate(self.slots):
            if s.free:
                continue
            if len(s.generated) > s.request.max_new_tokens:
                v.append(
                    f"slot {i} rid {s.request.rid}: generated "
                    f"{len(s.generated)} > budget {s.request.max_new_tokens}")
        return v

    def retire(self, slot: int, now: float, reason: str) -> ServeResult:
        s = self.slots[slot]
        assert not s.free
        req = s.request
        self.slots[slot] = SlotState()
        return ServeResult(
            rid=req.rid,
            tokens=np.asarray(s.generated, np.int32),
            finish_reason=reason,
            arrival_time=req.arrival_time,
            start_time=s.start_time,
            finish_time=now,
            decode_steps=s.decode_steps,
        )
