"""Per-request expert-preference scorers for the affinity scheduler.

Two providers, same (L, E) score contract as ``core.predictor``:

* ``prefill_expert_scores`` — "oracle" profile from the request's own
  prompt: one collect-probs forward pass, mean router distribution per
  layer. No training needed; this is the upper bound the Psi predictor
  approximates (Sec 3.1.2).
* ``predictor_expert_scores`` — the trained Psi_MLP over the frozen
  prompt embedder, the paper's deployable path (Eq. 7).
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.predictor import PromptEmbedder, predict_scores
from ..models.model import apply_model
from ..models.runtime import Runtime
from .request import ServeRequest


def prompt_router_profile(cfg: ModelConfig, params, prompt: np.ndarray, *,
                          rt: Optional[Runtime] = None, lora=None,
                          lora_scale: float = 1.0) -> np.ndarray:
    """One forward pass over the prompt -> (L, E) mean router probs."""
    rt = rt or Runtime(zero_drop=True)
    _, aux = apply_model(
        params, cfg, jnp.asarray(prompt, jnp.int32)[None], rt,
        collect_probs=True, lora=lora, lora_scale=lora_scale,
    )
    # aux["probs"]: list of (R, 1, T, E) per (group, position) -> (L, E)
    per_layer = [p[:, 0].mean(axis=1) for p in aux["probs"]]  # [(R, E), ...]
    return np.asarray(jnp.concatenate(per_layer, axis=0))


def prefill_expert_scores(cfg: ModelConfig, params,
                          requests: Sequence[ServeRequest], *,
                          rt: Optional[Runtime] = None, lora=None,
                          lora_scale: float = 1.0) -> List[np.ndarray]:
    """Annotate ``requests`` in place with oracle prompt profiles."""
    scores = []
    for r in requests:
        s = prompt_router_profile(cfg, params, r.prompt, rt=rt, lora=lora,
                                  lora_scale=lora_scale)
        r.expert_scores = s
        scores.append(s)
    return scores


def predictor_expert_scores(predictor_params, embedder: PromptEmbedder,
                            requests: Sequence[ServeRequest]) -> List[np.ndarray]:
    """Annotate ``requests`` in place with Psi predictor scores (Eq. 7)."""
    scores = []
    for r in requests:
        s = predict_scores(predictor_params, embedder(jnp.asarray(r.prompt)))
        r.expert_scores = s
        scores.append(s)
    return scores
