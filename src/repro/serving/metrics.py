"""Serving telemetry: throughput, request-latency percentiles, queue
depth, slot occupancy, and (on the offloaded path) expert-cache
transfers/hit-rate — reported per scheduling policy so the
MELINOE-vs-baseline gap under load is a single JSON diff."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

import numpy as np


@dataclass
class ServerMetrics:
    policy: str = "fcfs"
    decode_steps: int = 0  # batched decode iterations
    active_row_steps: int = 0  # slot-steps that advanced a live request
    total_row_steps: int = 0  # slot-steps paid for (n_slots * decode_steps)
    prefill_tokens: int = 0
    generated_tokens: int = 0
    wall_time: float = 0.0  # host seconds actually spent serving
    modeled_time: float = 0.0  # Eq. 3 virtual seconds (offloaded path)
    # both Eq.-3 clocks, accumulated side by side on the offloaded path:
    # serial charges compute + every transfer; overlapped hides layer
    # l+1's fetches under layer l's compute (always <= serial)
    modeled_time_serial: float = 0.0
    modeled_time_overlapped: float = 0.0
    latencies: List[float] = field(default_factory=list)
    queue_depth: List[int] = field(default_factory=list)
    # offloaded-path expert cache accounting
    transfers: int = 0
    transfer_bytes: int = 0
    prefetch_transfers: int = 0
    cache_hits: int = 0
    cache_misses: int = 0

    # -- recording ---------------------------------------------------------
    def observe_step(self, n_active: int, n_slots: int, backlog: int) -> None:
        self.decode_steps += 1
        self.active_row_steps += n_active
        self.total_row_steps += n_slots
        self.queue_depth.append(backlog)

    def observe_finish(self, latency: float) -> None:
        self.latencies.append(float(latency))

    # -- derived -----------------------------------------------------------
    @property
    def occupancy(self) -> float:
        """Mean fraction of slot-steps doing useful work."""
        return self.active_row_steps / self.total_row_steps if self.total_row_steps else 0.0

    @property
    def hit_rate(self) -> float:
        t = self.cache_hits + self.cache_misses
        return self.cache_hits / t if t else 0.0

    def latency_percentile(self, p: float) -> float:
        return float(np.percentile(self.latencies, p)) if self.latencies else 0.0

    def throughput_tok_s(self) -> float:
        """Generated tokens per second of serving time — Eq.-3 modeled
        seconds when the offloaded cost model drove the clock, else
        measured wall seconds."""
        t = self.modeled_time if self.modeled_time > 0 else self.wall_time
        return self.generated_tokens / t if t > 0 else 0.0

    def summary(self) -> Dict:
        return {
            "policy": self.policy,
            "requests": len(self.latencies),
            "decode_steps": self.decode_steps,
            "generated_tokens": self.generated_tokens,
            "prefill_tokens": self.prefill_tokens,
            "throughput_tok_s": self.throughput_tok_s(),
            "latency_p50": self.latency_percentile(50),
            "latency_p95": self.latency_percentile(95),
            "latency_p99": self.latency_percentile(99),
            "mean_queue_depth": float(np.mean(self.queue_depth)) if self.queue_depth else 0.0,
            "slot_occupancy": self.occupancy,
            "wall_time_s": self.wall_time,
            "modeled_time_s": self.modeled_time,
            # service-time-only clocks (no virtual idle between arrivals),
            # so serial vs overlapped compare like for like
            "modeled_time_serial_s": self.modeled_time_serial,
            "modeled_time_overlapped_s": self.modeled_time_overlapped,
            "service_throughput_serial_tok_s": (
                self.generated_tokens / self.modeled_time_serial
                if self.modeled_time_serial > 0 else 0.0
            ),
            "service_throughput_overlapped_tok_s": (
                self.generated_tokens / self.modeled_time_overlapped
                if self.modeled_time_overlapped > 0 else 0.0
            ),
            "transfers": self.transfers,
            "transfer_bytes": self.transfer_bytes,
            "prefetch_transfers": self.prefetch_transfers,
            "cache_hit_rate": self.hit_rate,
        }
