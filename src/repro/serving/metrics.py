"""Serving telemetry: throughput, request-latency percentiles, TTFT /
inter-token latency, queue depth, slot occupancy, and (on the offloaded
path) expert-cache transfers/hit-rate — reported per scheduling policy
so the MELINOE-vs-baseline gap under load is a single JSON diff.

Per-observation series (latencies, queue depth, TTFT, ITL) are rolling
windows of the last ``window`` observations so a long-lived server's
memory does not grow with request count; the aggregate counters
(``requests_finished``, exact queue-depth mean) are cumulative and never
lose history.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field, fields
from typing import Dict, List, Optional

import numpy as np


@dataclass
class ServerMetrics:
    policy: str = "fcfs"
    # rolling-window length for the per-observation series below
    window: int = 4096
    decode_steps: int = 0  # batched decode iterations
    active_row_steps: int = 0  # slot-steps that advanced a live request
    total_row_steps: int = 0  # slot-steps paid for (n_slots * decode_steps)
    prefill_tokens: int = 0
    generated_tokens: int = 0
    wall_time: float = 0.0  # host seconds actually spent serving
    modeled_time: float = 0.0  # Eq. 3 virtual seconds (offloaded path)
    # both Eq.-3 clocks, accumulated side by side on the offloaded path:
    # serial charges compute + every transfer; overlapped hides layer
    # l+1's fetches under layer l's compute (always <= serial)
    modeled_time_serial: float = 0.0
    modeled_time_overlapped: float = 0.0
    # rolling windows (deque(maxlen=window) after __post_init__); appends
    # keep working like lists, old observations fall off the front
    latencies: List[float] = field(default_factory=list)
    queue_depth: List[int] = field(default_factory=list)
    ttfts: List[float] = field(default_factory=list)  # time to first token
    itls: List[float] = field(default_factory=list)  # mean inter-token latency
    # cumulative counterparts that survive window eviction
    requests_finished: int = 0
    queue_depth_sum: float = 0.0
    queue_depth_count: int = 0
    # resilience / SLO accounting (PR 8)
    requests_shed: int = 0  # never admitted: queue bound overflow
    requests_expired: int = 0  # never admitted: SLO passed while queued
    deadline_retired: int = 0  # admitted but cut mid-decode at the SLO
    slo_attained: int = 0  # finished within SLO (or no SLO attached)
    degraded_requests: int = 0  # served >=1 little-expert substitution
    # offloaded-path expert cache accounting
    transfers: int = 0
    transfer_bytes: int = 0
    prefetch_transfers: int = 0
    cache_hits: int = 0
    cache_misses: int = 0

    def __post_init__(self):
        w = max(1, int(self.window))
        self.latencies = deque(self.latencies, maxlen=w)
        self.queue_depth = deque(self.queue_depth, maxlen=w)
        self.ttfts = deque(self.ttfts, maxlen=w)
        self.itls = deque(self.itls, maxlen=w)

    # -- recording ---------------------------------------------------------
    def observe_step(self, n_active: int, n_slots: int, backlog: int) -> None:
        self.decode_steps += 1
        self.active_row_steps += n_active
        self.total_row_steps += n_slots
        self.observe_queue_depth(backlog)

    def observe_queue_depth(self, depth: int) -> None:
        self.queue_depth.append(int(depth))
        self.queue_depth_sum += depth
        self.queue_depth_count += 1

    def observe_finish(self, latency: float, ttft: Optional[float] = None,
                       itl: Optional[float] = None) -> None:
        self.requests_finished += 1
        self.latencies.append(float(latency))
        if ttft is not None:
            self.ttfts.append(float(ttft))
        if itl is not None:
            self.itls.append(float(itl))

    # -- derived -----------------------------------------------------------
    @property
    def occupancy(self) -> float:
        """Mean fraction of slot-steps doing useful work."""
        return self.active_row_steps / self.total_row_steps if self.total_row_steps else 0.0

    @property
    def hit_rate(self) -> float:
        t = self.cache_hits + self.cache_misses
        return self.cache_hits / t if t else 0.0

    @staticmethod
    def _pct(series, p: float) -> float:
        return float(np.percentile(np.asarray(series), p)) if series else 0.0

    def latency_percentile(self, p: float) -> float:
        return self._pct(self.latencies, p)

    @property
    def mean_queue_depth(self) -> float:
        """Exact mean over EVERY observation, not just the window."""
        return (self.queue_depth_sum / self.queue_depth_count
                if self.queue_depth_count else 0.0)

    def throughput_tok_s(self) -> float:
        """Generated tokens per second of serving time — Eq.-3 modeled
        seconds when the offloaded cost model drove the clock, else
        measured wall seconds."""
        t = self.modeled_time if self.modeled_time > 0 else self.wall_time
        return self.generated_tokens / t if t > 0 else 0.0

    @property
    def requests_offered(self) -> int:
        """Everything that entered the system: finished + shed + expired
        (deadline-retired requests are counted in requests_finished)."""
        return self.requests_finished + self.requests_shed + self.requests_expired

    @property
    def slo_attainment(self) -> float:
        """Fraction of offered requests that finished within their SLO
        (best-effort requests count as attained when they finish) — the
        chaos benchmark's goodput numerator."""
        total = self.requests_offered
        return self.slo_attained / total if total else 0.0

    def goodput_req_s(self) -> float:
        """SLO-attained requests per second of serving time."""
        t = self.modeled_time if self.modeled_time > 0 else self.wall_time
        return self.slo_attained / t if t > 0 else 0.0

    # -- durable state (recovery checkpoints) -------------------------------
    def to_state(self) -> Dict:
        """Plain-python snapshot of every counter and rolling window —
        the ServerMetrics entry in a recovery checkpoint."""
        out = {}
        for f in fields(self):
            val = getattr(self, f.name)
            out[f.name] = list(val) if isinstance(val, deque) else val
        return out

    @classmethod
    def from_state(cls, state: Dict) -> "ServerMetrics":
        """Rebuild from :meth:`to_state` output. Unknown keys are
        ignored so old checkpoints survive field additions."""
        known = {f.name for f in fields(cls)}
        return cls(**{k: v for k, v in state.items() if k in known})

    def audit(self) -> List[str]:
        """Counter-sanity check (watchdog contract): non-negative
        cumulative counters and window/aggregate agreement. Returns
        violation strings, empty when healthy."""
        v = []
        for name in ("decode_steps", "prefill_tokens", "generated_tokens",
                     "requests_finished", "requests_shed", "requests_expired",
                     "deadline_retired", "slo_attained", "degraded_requests",
                     "transfers", "transfer_bytes", "cache_hits",
                     "cache_misses"):
            if getattr(self, name) < 0:
                v.append(f"negative counter {name}={getattr(self, name)}")
        if self.slo_attained > self.requests_finished:
            v.append(f"slo_attained={self.slo_attained} > "
                     f"requests_finished={self.requests_finished}")
        if self.deadline_retired > self.requests_finished:
            v.append(f"deadline_retired={self.deadline_retired} > "
                     f"requests_finished={self.requests_finished}")
        if len(self.latencies) > self.requests_finished:
            v.append(f"latency window {len(self.latencies)} > "
                     f"requests_finished={self.requests_finished}")
        if self.queue_depth_count < len(self.queue_depth):
            v.append(f"queue_depth_count={self.queue_depth_count} < "
                     f"window {len(self.queue_depth)}")
        return v

    def summary(self) -> Dict:
        return {
            "policy": self.policy,
            "requests": self.requests_finished,
            "decode_steps": self.decode_steps,
            "generated_tokens": self.generated_tokens,
            "prefill_tokens": self.prefill_tokens,
            "throughput_tok_s": self.throughput_tok_s(),
            "latency_p50": self.latency_percentile(50),
            "latency_p95": self.latency_percentile(95),
            "latency_p99": self.latency_percentile(99),
            "ttft_p50": self._pct(self.ttfts, 50),
            "ttft_p95": self._pct(self.ttfts, 95),
            "itl_p50": self._pct(self.itls, 50),
            "itl_p95": self._pct(self.itls, 95),
            "mean_queue_depth": self.mean_queue_depth,
            "slot_occupancy": self.occupancy,
            "wall_time_s": self.wall_time,
            "modeled_time_s": self.modeled_time,
            # service-time-only clocks (no virtual idle between arrivals),
            # so serial vs overlapped compare like for like
            "modeled_time_serial_s": self.modeled_time_serial,
            "modeled_time_overlapped_s": self.modeled_time_overlapped,
            "service_throughput_serial_tok_s": (
                self.generated_tokens / self.modeled_time_serial
                if self.modeled_time_serial > 0 else 0.0
            ),
            "service_throughput_overlapped_tok_s": (
                self.generated_tokens / self.modeled_time_overlapped
                if self.modeled_time_overlapped > 0 else 0.0
            ),
            "transfers": self.transfers,
            "transfer_bytes": self.transfer_bytes,
            "prefetch_transfers": self.prefetch_transfers,
            "cache_hit_rate": self.hit_rate,
            "requests_shed": self.requests_shed,
            "requests_expired": self.requests_expired,
            "deadline_retired": self.deadline_retired,
            "degraded_requests": self.degraded_requests,
            "slo_attained": self.slo_attained,
            "slo_attainment": self.slo_attainment,
            "goodput_req_s": self.goodput_req_s(),
        }

    def publish(self, registry=None, **labels) -> None:
        """Export the summary onto a :class:`~repro.obs.registry
        .MetricsRegistry` (global by default) as ``serve_*`` gauges,
        labeled with the scheduling policy."""
        if registry is None:
            from ..obs.registry import REGISTRY as registry
        labels = dict(labels, policy=self.policy)
        for k, v in self.summary().items():
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                registry.gauge(f"serve_{k}", "ServerMetrics.summary() field",
                               **labels).set(float(v))
