"""Deprecated alias for :mod:`repro.serving.scorers`.

The module was renamed — "profiling" now means the observability
subsystem (``repro.obs``); the request-scoring helpers live in
``scorers.py``. This shim re-exports them and warns once on import.
"""
from __future__ import annotations

import warnings

from .scorers import (  # noqa: F401
    predictor_expert_scores,
    prefill_expert_scores,
    prompt_router_profile,
)

warnings.warn(
    "repro.serving.profiling is deprecated; import from "
    "repro.serving.scorers instead",
    DeprecationWarning,
    stacklevel=2,
)

__all__ = [
    "predictor_expert_scores",
    "prefill_expert_scores",
    "prompt_router_profile",
]
