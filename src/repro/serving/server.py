"""Continuous-batching servers over both inference engines.

``ContinuousBatchingServer`` (fits-in-memory path) runs the jitted
single-step decode over a fixed pool of KV slots. Sequences live at
independent positions (the per-row ``pos`` vector threaded through
``decode_attend``); finished sequences retire on a stop token or their
token budget and the freed slot is re-prefilled with the next scheduled
request — no one is padded to the longest prompt or decoded past their
own budget.

``OffloadedWaveServer`` (memory-constrained path, Sec 3.2) drives the
``OffloadedMoEEngine``: the scheduler picks the next wave of requests,
the union of their predicted expert sets is prefetched (Eq. 7), and the
wave is decoded over the shared resident cache. Its clock advances by
the Eq. 3 cost model (demand misses AND prefetch DMA), so
latency/throughput reflect transfer traffic.

Clock semantics (continuous server): the virtual clock counts measured
host time for prefill + decode; jitted steps are pre-compiled in the
constructor so no XLA compile lands on a request's latency. Prefill
runs eagerly per prompt, so the first occurrence of a new prompt
LENGTH still pays per-op trace overhead inside the clock — bucket
prompt lengths upstream if tail latencies at many distinct lengths
matter.
"""
from __future__ import annotations

import time
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.offload_engine import HardwareProfile, OffloadedMoEEngine
from ..faults import FetchPolicy, get_fault_plan
from ..inference.engine import Request, ServingEngine, truncate_at_stop
from ..inference.sampling import greedy, sample_per_row
from ..models.model import decode_step, prefill
from ..models.runtime import Runtime
from ..obs.trace import clock_span, get_tracer
from .batch import BatchState
from .metrics import ServerMetrics
from .queue import RequestQueue
from .request import ServeRequest, ServeResult
from .scheduler import FCFSScheduler, Scheduler


def _reject_unservable(queue: RequestQueue, now: float, mt: ServerMetrics,
                       results: List[ServeResult], tr, jr=None) -> None:
    """Admission control: turn bound-overflow and expired-while-queued
    requests into "shed" results — they never reach a slot or wave.
    ``drop_expired`` routes its victims through the queue's shed pool,
    so one drain covers both kinds; identity tells them apart. ``jr``
    (a recovery ``RequestJournal``) makes each shed durable."""
    expired = {id(r) for r in queue.drop_expired(now)}
    queue.enforce_bound(now)
    for r in queue.drain_shed():
        if id(r) in expired:
            mt.requests_expired += 1
        else:
            mt.requests_shed += 1
        if tr.enabled:
            tr.instant("serve.shed", rid=r.rid, expired=id(r) in expired,
                       wait_s=now - r.arrival_time)
        if jr is not None:
            jr.shed(r, expired=id(r) in expired, now=now)
        results.append(ServeResult(
            rid=r.rid, tokens=np.zeros(0, np.int32), finish_reason="shed",
            arrival_time=r.arrival_time, start_time=now, finish_time=now,
        ))


class ContinuousBatchingServer:
    """In-flight batching over the jitted fused decode step."""

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        n_slots: int = 4,
        max_len: int = 128,
        scheduler: Optional[Scheduler] = None,
        rt: Optional[Runtime] = None,
        lora=None,
        lora_scale: float = 1.0,
        window_override: Optional[int] = None,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.params = params
        self.rt = rt or Runtime(zero_drop=True)
        self.scheduler = scheduler or FCFSScheduler()
        self.n_slots = n_slots
        self.max_len = max_len
        self.lora = lora
        self.lora_scale = lora_scale
        self.window_override = window_override
        self.seed = seed  # recorded in recovery checkpoints
        self._key0 = jax.random.key(seed)

        def _decode(params, tokens, cache):
            return decode_step(
                params, cfg, tokens, cache, self.rt,
                window_override=window_override, lora=lora, lora_scale=lora_scale,
            )

        self._decode_jit = jax.jit(_decode)

        def _sample(logits, rids, steps, temps):
            # request-keyed per-row sampling: randomness follows the
            # (rid, step) pair, not the slot, so batch composition never
            # perturbs a sequence; keys derive inside the jit to keep the
            # per-step host work to three small array transfers
            keys = jax.vmap(
                lambda r, s: jax.random.fold_in(jax.random.fold_in(self._key0, r), s)
            )(rids, steps)
            return sample_per_row(logits, None, temps, keys=keys)

        self._sample_jit = jax.jit(_sample)
        self._insert_jit = jax.jit(self._insert_row)
        self.cache = self._fresh_cache()
        # warm every compilation now so the serving clock (latency
        # percentiles, queue-depth trace) never charges XLA compile time
        # to the first requests
        dummy = jnp.zeros((n_slots, 1), jnp.int32)
        self._decode_jit(self.params, dummy, self.cache)
        self._sample_jit(
            jnp.zeros((n_slots, 1, cfg.vocab), jnp.float32),
            jnp.zeros((n_slots,), jnp.int32),
            jnp.zeros((n_slots,), jnp.int32),
            jnp.ones((n_slots,), jnp.float32),
        )
        _, pre = prefill(self.params, cfg, dummy[:1], self.rt, n_slots=max_len,
                         window_override=window_override, lora=lora,
                         lora_scale=lora_scale)
        self._insert_jit(self.cache, pre, 0)

    # ------------------------------------------------------------------
    def _fresh_cache(self):
        """Slot-pool cache: a dummy 1-token prefill fixes the tree
        structure (ring sizes etc.) to exactly what per-request prefills
        produce; rows are garbage until a request is inserted."""
        dummy = jnp.zeros((self.n_slots, 1), jnp.int32)
        _, cache = prefill(
            self.params, self.cfg, dummy, self.rt, n_slots=self.max_len,
            window_override=self.window_override,
            lora=self.lora, lora_scale=self.lora_scale,
        )
        cache["pos"] = jnp.zeros((self.n_slots,), jnp.int32)  # per-row positions
        return cache

    @staticmethod
    def _insert_row(cache, pre_cache, slot):
        """Splice a freshly prefilled request (batch of 1) into slot
        ``slot`` of the pooled cache. Group leaves are stacked
        (R, B, ...), so one tree_map covers KV, ring positions and SSM
        state alike."""
        out = {"pos": cache["pos"].at[slot].set(pre_cache["pos"])}
        for g, sub in cache.items():
            if g == "pos":
                continue
            out[g] = jax.tree.map(
                lambda big, small: big.at[:, slot].set(small[:, 0]), sub, pre_cache[g]
            )
        return out

    # ------------------------------------------------------------------
    def _admit(self, state: BatchState, slot: int, req: ServeRequest,
               cur: np.ndarray, now: float, mt: ServerMetrics) -> Optional[str]:
        """Prefill one request into a free slot; start_time is the
        admission moment (queueing ends, service begins). Returns the
        finish reason if the request completed immediately (budget of
        1 / instant stop) — the caller retires it with a clock that
        includes this prefill's cost.

        A request resumed from a crash re-prefills ``prompt + resumed``
        (its journaled watermark); greedy decode depends only on the
        token prefix, so the continuation is token-identical to the
        uninterrupted run."""
        inp = (req.prompt if req.resumed is None else
               np.concatenate([req.prompt, req.resumed]).astype(np.int32))
        logits, pre_cache = prefill(
            self.params, self.cfg, jnp.asarray(inp, jnp.int32)[None],
            self.rt, n_slots=self.max_len, window_override=self.window_override,
            lora=self.lora, lora_scale=self.lora_scale,
        )
        self.cache = self._insert_jit(self.cache, pre_cache, slot)
        state.occupy(slot, req, now)
        mt.prefill_tokens += len(inp)
        # first generated token comes from the prefill logits (greedy, to
        # match ServingEngine.generate_batch semantics)
        tok = int(np.asarray(greedy(logits))[0, 0])
        cur[slot, 0] = tok
        mt.generated_tokens += 1
        return state.append_token(slot, tok)

    def run(self, queue: RequestQueue,
            metrics: Optional[ServerMetrics] = None,
            *,
            journal=None,
            checkpoint_every: Optional[int] = None,
            audit_every: Optional[int] = None,
            resume=None,
            on_step=None,
            should_drain=None,
            ) -> Tuple[List[ServeResult], ServerMetrics]:
        """Serve the queue. Crash-safety knobs (all optional):

        * ``journal`` — a ``recovery.RequestJournal``; every arrival /
          admit / emitted-token watermark / retire / shed lands as a
          flushed JSONL event
        * ``checkpoint_every`` — snapshot + journal rotation every N
          decode steps (requires ``journal``)
        * ``audit_every`` — run the invariant watchdog every N steps
        * ``resume`` — a ``recovery.RecoveredState``; the clock, step
          counter and finished results continue from it (pass
          ``resume.metrics`` as ``metrics`` and a queue built via
          ``resume.build_queue()`` for full continuity)
        * ``on_step`` — liveness hook called after every decode step
          with a dict (step/now/backlog/in_flight/finished/generated);
          the fleet worker heartbeats (and injects worker faults) here
        * ``should_drain`` — polled each loop iteration; once it
          returns True admission stops, in-flight requests finish, a
          final checkpoint anchors the journal, and ``self.drained``
          is set — still-pending requests stay journaled for a resume
        """
        mt = metrics or ServerMetrics(policy=self.scheduler.name)
        tr = get_tracer()
        plan = get_fault_plan()
        jr = journal
        state = BatchState(self.n_slots, self.max_len)
        cur = np.zeros((self.n_slots, 1), np.int32)
        results: List[ServeResult] = []
        # virtual first-token time per live rid, for TTFT/ITL at retire
        first_tok: dict = {}
        now = 0.0
        step_idx = 0
        wd = None
        if resume is not None:
            now = resume.now
            step_idx = resume.step
            results = list(resume.results)
        if audit_every or resume is not None:
            from ..recovery.audit import Watchdog
            wd = Watchdog(queue=queue, metrics=mt, batch=state,
                          offered_base=resume.offered_base if resume else 0)
            if resume is not None:
                wd.check(in_flight=0)  # trust nothing restored, audited
        if jr is not None:
            for r in queue.pending():
                jr.arrival(r)
        t_wall0 = time.perf_counter()

        def _retire(s: int, reason: str) -> None:
            req = state.slots[s].request
            res = state.retire(s, now, reason)
            attained = False
            if reason == "deadline":
                mt.deadline_retired += 1
            elif req.deadline is None or now <= req.deadline:
                mt.slo_attained += 1
                attained = True
            ft = first_tok.pop(res.rid, None)
            ttft = None if ft is None else ft - res.arrival_time
            itl = (None if ft is None else
                   (now - ft) / max(len(res.tokens) - 1, 1))
            mt.observe_finish(res.latency, ttft=ttft, itl=itl)
            if tr.enabled:
                tr.instant("serve.retire", rid=res.rid, reason=reason,
                           tokens=len(res.tokens))
            if jr is not None:
                jr.retire(res, plen=req.prompt_len, attained=attained,
                          ttft=ttft, itl=itl)
            results.append(res)

        self.drained = False
        while len(queue) or state.active_slots():
            draining = should_drain is not None and should_drain()
            # -- admission control: shed what can't be served -----------
            _reject_unservable(queue, now, mt, results, tr, jr)
            # -- admission: scheduler fills freed slots -----------------
            free = state.free_slots() if not draining else []
            if free:
                ready = queue.ready(now)
                if ready:
                    order = self.scheduler.order(ready, hot=state.active_requests())
                    for slot, req in zip(free, order):
                        queue.admit(req)
                        if tr.enabled:
                            tr.instant("serve.queue_wait", rid=req.rid,
                                       wait_s=now - req.arrival_time)
                        # prefill is service time: the clock_span both
                        # advances the serving clock and (when tracing)
                        # records the same interval as a span
                        with clock_span("serve.prefill", rid=req.rid,
                                        prompt_len=req.prompt_len) as cs:
                            reason = self._admit(state, slot, req, cur, now, mt)
                        now += cs.dur
                        # the first token materializes with the prefill
                        first_tok[req.rid] = now
                        if jr is not None:
                            jr.admit(req.rid, now)
                            jr.watermark({req.rid: [int(cur[slot, 0])]}, now)
                        if reason is not None:
                            _retire(slot, reason)
                        elif req.deadline is not None and now >= req.deadline:
                            # earlier admissions' prefills ate the budget
                            _retire(slot, "deadline")
            active = state.active_slots()
            if not active:
                if draining:
                    break  # nothing in flight: pending stays journaled
                # idle: jump the virtual clock to the next arrival
                nxt = queue.next_arrival()
                if nxt is not None:
                    now = max(now, nxt)
                continue

            # injected crash: raises InjectedCrash between steps — the
            # journal is flushed through the last completed step, so
            # recovery resumes exactly here
            if plan.enabled:
                plan.maybe_crash("serve.decode")

            # -- one fused decode step over the whole slot pool ---------
            with clock_span("serve.decode_step", active=len(active),
                            slots=self.n_slots) as cs:
                logits, self.cache, _ = self._decode_jit(
                    self.params, jnp.asarray(cur), self.cache
                )
                temps = np.zeros(self.n_slots, np.float32)
                # filler (rid, step) for free/greedy rows: any non-negative
                # value works, the draw is discarded by the temperature mask
                rids = np.arange(self.n_slots, dtype=np.int32) + (2**31 - 1 - self.n_slots)
                steps = np.zeros(self.n_slots, np.int32)
                for s in active:
                    slot = state.slots[s]
                    temps[s] = slot.request.temperature
                    rids[s] = slot.request.rid
                    steps[s] = len(slot.generated)
                if np.any(temps > 0):
                    toks = self._sample_jit(logits, jnp.asarray(rids),
                                            jnp.asarray(steps), jnp.asarray(temps))
                else:
                    toks = greedy(logits)
                toks_np = np.asarray(toks)
            # charge the step (plus any injected scheduler hiccup) before
            # retiring
            now += cs.dur + plan.step_delay()

            step_toks: dict = {}
            retire_now: List[Tuple[int, str]] = []
            for s in active:
                state.slots[s].decode_steps += 1
                tok = int(toks_np[s, 0])
                cur[s, 0] = tok
                mt.generated_tokens += 1
                step_toks[state.slots[s].request.rid] = [tok]
                reason = state.append_token(s, tok)
                if reason is None:
                    dl = state.slots[s].request.deadline
                    if dl is not None and now >= dl:
                        reason = "deadline"
                if reason is not None:
                    retire_now.append((s, reason))
            mt.observe_step(len(active), self.n_slots, queue.backlog(now))
            # watermark BEFORE the retires so replay sees tokens first
            if jr is not None:
                jr.watermark(step_toks, now)
            for s, reason in retire_now:
                _retire(s, reason)

            step_idx += 1
            if on_step is not None:
                on_step({"step": step_idx, "now": now,
                         "backlog": queue.backlog(now),
                         "in_flight": len(state.active_slots()),
                         "finished": mt.requests_finished,
                         "generated": mt.generated_tokens})
            if wd is not None and audit_every and step_idx % audit_every == 0:
                wd.check(in_flight=len(state.active_slots()))
            if (jr is not None and checkpoint_every
                    and step_idx % checkpoint_every == 0):
                from ..recovery.checkpoint import save_server_checkpoint
                ck = jr.checkpoint_path(step_idx)
                # a slot's absolute watermark is its generated list; the
                # record folds the resumed prefix in itself, so hand it
                # only the tokens emitted THIS incarnation
                inflight = [
                    (state.slots[s].request,
                     state.slots[s].generated[
                         state.slots[s].request.n_resumed:])
                    for s in state.active_slots()
                ]
                save_server_checkpoint(
                    ck, kind="continuous", step=step_idx, now=now,
                    seed=self.seed, policy=self.scheduler.name,
                    pending=queue.pending(), inflight=inflight,
                    results=results, metrics=mt)
                jr.rotate(ck, step_idx, now)

        _reject_unservable(queue, now, mt, results, tr, jr)
        self.drained = should_drain is not None and should_drain()
        if jr is not None and self.drained:
            # final drain checkpoint: everything finished or pending is
            # anchored, so a later --resume (or a fleet re-offer) picks
            # up exactly here with no journal tail to replay
            from ..recovery.checkpoint import save_server_checkpoint
            ck = jr.checkpoint_path(step_idx)
            save_server_checkpoint(
                ck, kind="continuous", step=step_idx, now=now,
                seed=self.seed, policy=self.scheduler.name,
                pending=queue.pending(), inflight=[],
                results=results, metrics=mt)
            jr.rotate(ck, step_idx, now)
        mt.wall_time += time.perf_counter() - t_wall0
        return sorted(results, key=lambda r: r.rid), mt


# ---------------------------------------------------------------------------
# Static-batching baseline (for the continuous-vs-static comparison)
# ---------------------------------------------------------------------------


def serve_static(cfg: ModelConfig, params, requests: Sequence[ServeRequest], *,
                 batch_size: int, rt: Optional[Runtime] = None,
                 ) -> Tuple[List[ServeResult], int]:
    """Serve in arrival-order chunks with the padded static engine; every
    request in a chunk decodes to the chunk max budget. Returns results
    (stop-token truncated) and the total number of decode iterations."""
    eng = ServingEngine(cfg, params, rt=rt, max_batch=batch_size)
    ordered = sorted(requests, key=lambda r: (r.arrival_time, r.rid))
    results: List[ServeResult] = []
    decode_iters = 0
    for i in range(0, len(ordered), batch_size):
        chunk = ordered[i : i + batch_size]
        comps = eng.generate_batch([
            Request(prompt=r.prompt, max_new_tokens=r.max_new_tokens,
                    temperature=r.temperature) for r in chunk
        ])
        decode_iters += max(r.max_new_tokens for r in chunk) - 1
        for r, c in zip(chunk, comps):
            toks, reason = truncate_at_stop(c.tokens, r.stop_tokens)
            results.append(ServeResult(rid=r.rid, tokens=toks, finish_reason=reason,
                                       arrival_time=r.arrival_time))
    return sorted(results, key=lambda r: r.rid), decode_iters


# ---------------------------------------------------------------------------
# Offloaded path: scheduler-driven prefetch between batch waves
# ---------------------------------------------------------------------------


class OffloadedWaveServer:
    """Wave scheduling over the offloaded expert cache (Sec 3.2).

    Requests are served greedily in scheduler order, ``wave_size`` at a
    time; before each wave the mean of the wave's predicted expert
    scores is prefetched so the resident set matches the co-scheduled
    requests. The expert cache (and its residency) persists across
    waves — that persistence is exactly what the affinity policy
    exploits. The serving clock advances by the Eq. 3 cost model:
    serial by default, or the engine's overlapped clock (layer ``l``'s
    router output issues layer ``l+1``'s fetches) with ``overlap=True``.
    Both cumulative modeled times are reported either way."""

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        capacity: int,
        policy: str = "lfu",
        gamma: float = 0.9,
        scheduler: Optional[Scheduler] = None,
        wave_size: int = 4,
        quantized: bool = False,
        hw: HardwareProfile = HardwareProfile(),
        use_prefetch: bool = True,
        lora=None,
        lora_scale: float = 1.0,
        overlap: bool = False,
        engine_impl: str = "slab",
        little_experts: bool = False,
        little_rank: int = 8,
        little_quantized: bool = False,
        fetch_policy: Optional[FetchPolicy] = None,
        pressure_frac: float = 0.75,
        max_backlog: Optional[int] = None,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.seed = seed  # recorded in recovery checkpoints
        self.scheduler = scheduler or FCFSScheduler()
        self.wave_size = wave_size
        self.hw = hw
        self.use_prefetch = use_prefetch
        self.overlap = overlap
        self.max_backlog = max_backlog
        self.engine = OffloadedMoEEngine(
            cfg, params, capacity=capacity, policy=policy, gamma=gamma,
            quantized=quantized, hw=hw, lora=lora, lora_scale=lora_scale,
            impl=engine_impl, little_experts=little_experts,
            little_rank=little_rank, little_quantized=little_quantized,
            fetch_policy=fetch_policy, pressure_frac=pressure_frac,
        )

    def run(self, queue: RequestQueue,
            metrics: Optional[ServerMetrics] = None,
            *,
            journal=None,
            checkpoint_every: Optional[int] = None,
            audit_every: Optional[int] = None,
            resume=None,
            on_step=None,
            should_drain=None,
            ) -> Tuple[List[ServeResult], ServerMetrics]:
        """Serve the queue. Same crash-safety knobs as
        :meth:`ContinuousBatchingServer.run`, on wave granularity:
        checkpoints land every ``checkpoint_every`` waves (with the
        engine's cache state for warm revival — in-flight is always
        empty because requests are atomic within a wave), the watchdog
        runs every ``audit_every`` waves, ``on_step`` fires once per
        completed wave, and ``should_drain`` stops scheduling further
        waves (a wave is atomic, so drain waits for the current one,
        writes a final anchored checkpoint, and sets ``self.drained``).
        Revive the engine (``engine.revive(resume.engine["cache"])`` +
        restoring ``engine.metrics``) before calling run with
        ``resume``."""
        mt = metrics or ServerMetrics(policy=self.scheduler.name)
        tr = get_tracer()
        plan = get_fault_plan()
        eng = self.engine
        jr = journal
        results: List[ServeResult] = []
        now = 0.0
        wave_idx = 0
        wd = None
        if resume is not None:
            now = resume.now
            wave_idx = resume.step
            results = list(resume.results)
        if audit_every or resume is not None:
            from ..recovery.audit import Watchdog
            wd = Watchdog(queue=queue, metrics=mt, engine=eng,
                          offered_base=resume.offered_base if resume else 0)
            if resume is not None:
                wd.check(in_flight=0)  # trust nothing restored, audited
        if jr is not None:
            for r in queue.pending():
                jr.arrival(r)
        t_wall0 = time.perf_counter()
        prev_wave: List[ServeRequest] = []
        if self.max_backlog is not None:
            queue.set_bound(self.max_backlog)

        self.drained = False
        while len(queue):
            if should_drain is not None and should_drain():
                break
            # -- admission control: shed what can't be served -----------
            _reject_unservable(queue, now, mt, results, tr, jr)
            if not len(queue):
                break
            ready = queue.ready(now)
            if not ready:
                now = max(now, queue.next_arrival())
                continue
            order = self.scheduler.order(ready, hot=prev_wave)
            wave = order[: self.wave_size]
            mt.observe_queue_depth(queue.backlog(now))
            # injected scheduling hiccup (traffic-burst / host jitter)
            now += plan.step_delay()

            if self.use_prefetch:
                scored = [r.expert_scores for r in wave if r.expert_scores is not None]
                if scored:
                    # prefetch DMA is real link traffic: charge it to the
                    # wave on the same Eq. 3 terms as demand misses (it
                    # precedes the wave, so it is not hidden under either
                    # clock — both accumulators advance equally)
                    p_tx0 = eng.metrics.prefetch_transfers
                    p_b0 = eng.metrics.prefetch_bytes
                    fd0 = eng.metrics.fault_delay_s
                    eng.prefetch(np.mean(scored, axis=0))
                    dt = (
                        (eng.metrics.prefetch_bytes - p_b0) / self.hw.host_link_bw
                        + (eng.metrics.prefetch_transfers - p_tx0)
                        * self.hw.transfer_latency
                        # spike/retry stall injected during the prefetch:
                        # no step record is open, so the cumulative
                        # fault-delay delta is exactly the prefetch's
                        # share (request deltas below can't see it —
                        # their baselines are read after this point)
                        + (eng.metrics.fault_delay_s - fd0)
                    )
                    now += dt
                    mt.modeled_time_serial += dt
                    mt.modeled_time_overlapped += dt

            for req in wave:
                queue.admit(req)
                if jr is not None:
                    jr.admit(req.rid, now)
                if tr.enabled:
                    tr.instant("serve.queue_wait", rid=req.rid,
                               wait_s=now - req.arrival_time)
                start = now
                before_s = eng.metrics.modeled_time(self.hw)
                step0 = len(eng.metrics.step_flops)
                host0 = eng.metrics.host_time
                deg0 = eng.metrics.degraded_uses
                # SLO budget left on the engine's own (serial) clock
                deadline_s = (None if req.slo is None
                              else max(req.deadline - now, 0.0))
                # a request resumed from a crash re-prefills up to its
                # journaled watermark and only generates the remainder
                inp = (req.prompt if req.resumed is None else
                       np.concatenate([req.prompt, req.resumed])
                       .astype(np.int32))
                res = eng.generate(inp[None, :],
                                   max_new_tokens=(req.max_new_tokens
                                                   - req.n_resumed),
                                   quality=req.quality, deadline_s=deadline_s)
                d_serial = eng.metrics.modeled_time(self.hw) - before_s
                # delta over only this request's recorded steps — not a
                # re-walk of the whole accumulated history per request
                d_overlap = (eng.metrics.overlapped_span(self.hw, step0)
                             + eng.metrics.host_time - host0)
                # the prefill step alone (step0) dates the first token on
                # whichever Eq.-3 clock drives this server's time
                d_first = (eng.metrics.overlapped_span(self.hw, step0, step0 + 1)
                           if self.overlap else
                           eng.metrics.serial_span(self.hw, step0, step0 + 1))
                # consumed: don't retain per-step arrays for the whole run
                eng.metrics.drop_step_records(self.hw)
                mt.modeled_time_serial += d_serial
                mt.modeled_time_overlapped += d_overlap
                now += d_overlap if self.overlap else d_serial
                new = np.asarray(res["tokens"])[0]
                full = (new if req.resumed is None else
                        np.concatenate([req.resumed, new]))
                toks, reason = truncate_at_stop(full, req.stop_tokens)
                if res.get("stopped_early") and reason == "length":
                    reason = "deadline"  # cut mid-decode at the SLO
                degraded = eng.metrics.degraded_uses > deg0
                first_tok_time = start + d_first
                # the resumed prefix was generated (and counted) before
                # the crash; only this incarnation's tokens count here
                n_new = len(toks) - req.n_resumed
                mt.generated_tokens += n_new
                mt.prefill_tokens += len(inp)
                mt.decode_steps += n_new
                ttft = first_tok_time - req.arrival_time
                itl = (now - first_tok_time) / max(len(toks) - 1, 1)
                mt.observe_finish(now - req.arrival_time, ttft=ttft, itl=itl)
                attained = False
                if reason == "deadline":
                    mt.deadline_retired += 1
                elif req.slo is None or now <= req.deadline:
                    mt.slo_attained += 1
                    attained = True
                if degraded:
                    mt.degraded_requests += 1
                if tr.enabled:
                    tr.instant("serve.retire", rid=req.rid, reason=reason,
                               tokens=len(toks))
                result = ServeResult(
                    rid=req.rid, tokens=toks, finish_reason=reason,
                    arrival_time=req.arrival_time, start_time=start,
                    finish_time=now, decode_steps=n_new, degraded=degraded,
                )
                if jr is not None:
                    # watermark BEFORE retire so replay sees tokens first
                    jr.watermark(
                        {req.rid: [int(t) for t in toks[req.n_resumed:]]},
                        now)
                    jr.retire(result, plen=len(inp), attained=attained,
                              ttft=ttft, itl=itl)
                results.append(result)
            prev_wave = wave

            wave_idx += 1
            if on_step is not None:
                on_step({"step": wave_idx, "now": now,
                         "backlog": queue.backlog(now), "in_flight": 0,
                         "finished": mt.requests_finished,
                         "generated": mt.generated_tokens})
            if wd is not None and audit_every and wave_idx % audit_every == 0:
                wd.check(in_flight=0)
            if (jr is not None and checkpoint_every
                    and wave_idx % checkpoint_every == 0):
                from ..recovery.checkpoint import save_server_checkpoint
                ck = jr.checkpoint_path(wave_idx)
                save_server_checkpoint(
                    ck, kind="wave", step=wave_idx, now=now,
                    seed=self.seed, policy=self.scheduler.name,
                    pending=queue.pending(), inflight=[],
                    results=results, metrics=mt,
                    engine={"cache": eng.cache_state(),
                            "metrics": eng.metrics.state()})
                jr.rotate(ck, wave_idx, now)

        _reject_unservable(queue, now, mt, results, tr, jr)
        self.drained = should_drain is not None and should_drain()
        if jr is not None and self.drained:
            from ..recovery.checkpoint import save_server_checkpoint
            ck = jr.checkpoint_path(wave_idx)
            save_server_checkpoint(
                ck, kind="wave", step=wave_idx, now=now,
                seed=self.seed, policy=self.scheduler.name,
                pending=queue.pending(), inflight=[],
                results=results, metrics=mt,
                engine={"cache": eng.cache_state(),
                        "metrics": eng.metrics.state()})
            jr.rotate(ck, wave_idx, now)

        stats = eng.cache.stats()
        mt.transfers = eng.metrics.transfers
        mt.transfer_bytes = eng.metrics.transfer_bytes
        mt.prefetch_transfers = eng.metrics.prefetch_transfers
        mt.cache_hits, mt.cache_misses = stats.hits, stats.misses
        mt.modeled_time = now
        mt.wall_time += time.perf_counter() - t_wall0
        return sorted(results, key=lambda r: r.rid), mt
