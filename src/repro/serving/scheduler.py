"""Pluggable admission schedulers.

``order(ready, hot=...)`` returns the ready requests in admission
order; the server takes as many off the front as it has capacity for.
``hot`` is the set of requests whose experts are currently resident
(active slots / previous wave) — only the affinity policy looks at it.

* ``fcfs``            — arrival order (the latency-fair baseline)
* ``sjf``             — shortest job first by prompt+budget token work
* ``expert-affinity`` — greedy chaining by predicted expert-set overlap
  (Eq. 7 Top-C sets): each pick maximizes overlap with the experts
  already hot (active requests / previous wave), so co-scheduled
  sequences share the resident cache and CPU<->GPU transfers stay at the
  Eq. 3 floor. This is the serving-side analogue of MELINOE's
  fine-tuned routing concentration: the smaller and more cluster-stable
  the per-request expert sets, the more the scheduler can exploit them.
"""
from __future__ import annotations

from typing import List, Sequence

from .request import ServeRequest


class Scheduler:
    name = "base"

    def order(self, ready: Sequence[ServeRequest], *,
              hot: Sequence[ServeRequest] = ()) -> List[ServeRequest]:
        raise NotImplementedError


class FCFSScheduler(Scheduler):
    name = "fcfs"

    def order(self, ready, *, hot=()):
        return sorted(ready, key=lambda r: (r.arrival_time, r.rid))


class SJFScheduler(Scheduler):
    name = "sjf"

    def order(self, ready, *, hot=()):
        return sorted(ready, key=lambda r: (r.job_size, r.arrival_time, r.rid))


class ExpertAffinityScheduler(Scheduler):
    """Greedy max-overlap chaining over predicted Top-C expert sets."""

    name = "expert-affinity"

    def __init__(self, top_c: int = 4):
        self.top_c = top_c

    def _set(self, req: ServeRequest) -> frozenset:
        # memoized on the request object itself (not rid): a scheduler
        # reused across workloads must never serve stale sets, and the
        # cache dies with the request
        cached = getattr(req, "_expert_set_memo", None)
        if cached is None or cached[0] != self.top_c:
            cached = (self.top_c, req.expert_set(self.top_c))
            req._expert_set_memo = cached
        return cached[1]

    def order(self, ready, *, hot=()):
        remaining = sorted(ready, key=lambda r: (r.arrival_time, r.rid))
        resident: set = set()
        for r in hot:
            resident |= self._set(r)
        out: List[ServeRequest] = []
        while remaining:
            if resident:
                # max overlap with the resident experts; FCFS tie-break
                best = max(
                    remaining,
                    key=lambda r: (len(self._set(r) & resident),
                                   -r.arrival_time, -r.rid),
                )
            else:  # cold start: seed the chain with the oldest request
                best = remaining[0]
            remaining.remove(best)
            out.append(best)
            resident |= self._set(best)
        return out


SCHEDULERS = {
    "fcfs": FCFSScheduler,
    "sjf": SJFScheduler,
    "expert-affinity": ExpertAffinityScheduler,
}


def get_scheduler(name: str, **kwargs) -> Scheduler:
    if name not in SCHEDULERS:
        raise KeyError(f"unknown scheduler {name!r}; options: {sorted(SCHEDULERS)}")
    return SCHEDULERS[name](**kwargs)
