"""Request queue + synthetic traffic generation.

Arrivals are simulated on a virtual clock (seconds). ``poisson`` draws
i.i.d. exponential inter-arrival gaps at ``rate`` req/s; ``bursty``
releases requests in bursts of ``burst_size`` (the adversarial case for
an affinity scheduler: a burst mixes clusters); ``all_at_once`` puts the
whole workload at t=0 (closed-loop saturation benchmarks).

Prompts are drawn from the ``ClusterLM`` distribution so the workload
carries the latent cluster structure MELINOE exploits: same-cluster
requests share token pools, hence routing, hence cacheable expert sets.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..data.synthetic import ClusterLM
from .request import ServeRequest


class RequestQueue:
    """Arrival-ordered pending pool; the scheduler picks admission order."""

    def __init__(self, requests: Sequence[ServeRequest] = ()):
        self._pending: List[ServeRequest] = sorted(
            requests, key=lambda r: (r.arrival_time, r.rid)
        )

    def push(self, req: ServeRequest) -> None:
        self._pending.append(req)
        self._pending.sort(key=lambda r: (r.arrival_time, r.rid))

    def ready(self, now: float) -> List[ServeRequest]:
        """Requests that have arrived and are not yet admitted."""
        return [r for r in self._pending if r.arrival_time <= now]

    def admit(self, req: ServeRequest) -> None:
        self._pending.remove(req)

    def next_arrival(self) -> Optional[float]:
        return self._pending[0].arrival_time if self._pending else None

    def backlog(self, now: float) -> int:
        """Queue depth: arrived but not yet admitted."""
        return len(self.ready(now))

    def __len__(self) -> int:
        return len(self._pending)


@dataclass(frozen=True)
class TrafficConfig:
    n_requests: int = 16
    arrival: str = "poisson"  # "poisson" | "bursty" | "all_at_once"
    rate: float = 4.0  # mean arrival rate, requests / virtual second
    burst_size: int = 4
    prompt_len: Tuple[int, int] = (8, 32)  # inclusive range
    max_new_tokens: Tuple[int, int] = (4, 32)  # inclusive range
    temperature: float = 0.0
    stop_tokens: Tuple[int, ...] = ()
    n_clusters: Optional[int] = None  # restrict to the first k clusters
    seed: int = 0


def synthesize_workload(lm: ClusterLM, tcfg: TrafficConfig) -> List[ServeRequest]:
    """Sample a request trace over the ClusterLM prompt distribution."""
    rng = np.random.default_rng(tcfg.seed)
    n = tcfg.n_requests

    if tcfg.arrival == "poisson":
        gaps = rng.exponential(1.0 / max(tcfg.rate, 1e-9), n)
        arrivals = np.cumsum(gaps)
    elif tcfg.arrival == "bursty":
        burst_gap = tcfg.burst_size / max(tcfg.rate, 1e-9)
        arrivals = np.asarray([(i // tcfg.burst_size) * burst_gap for i in range(n)])
    elif tcfg.arrival == "all_at_once":
        arrivals = np.zeros(n)
    else:
        raise ValueError(f"unknown arrival process: {tcfg.arrival!r}")

    k_max = tcfg.n_clusters or lm.cfg.n_clusters
    reqs = []
    for i in range(n):
        cluster = int(rng.integers(k_max))
        plen = int(rng.integers(tcfg.prompt_len[0], tcfg.prompt_len[1] + 1))
        seq, _ = lm.sample_sequence(rng, cluster=cluster)
        prompt = seq[:plen].astype(np.int32)
        max_new = int(rng.integers(tcfg.max_new_tokens[0], tcfg.max_new_tokens[1] + 1))
        reqs.append(
            ServeRequest(
                rid=i,
                prompt=prompt,
                max_new_tokens=max_new,
                temperature=tcfg.temperature,
                stop_tokens=tcfg.stop_tokens,
                arrival_time=float(arrivals[i]),
                cluster=cluster,
            )
        )
    return reqs
