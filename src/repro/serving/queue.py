"""Request queue + synthetic traffic generation.

Arrivals are simulated on a virtual clock (seconds). ``poisson`` draws
i.i.d. exponential inter-arrival gaps at ``rate`` req/s; ``bursty``
releases requests in bursts of ``burst_size`` (the adversarial case for
an affinity scheduler: a burst mixes clusters); ``all_at_once`` puts the
whole workload at t=0 (closed-loop saturation benchmarks).

Prompts are drawn from the ``ClusterLM`` distribution so the workload
carries the latent cluster structure MELINOE exploits: same-cluster
requests share token pools, hence routing, hence cacheable expert sets.
"""
from __future__ import annotations

from bisect import insort
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..data.synthetic import ClusterLM
from .request import ServeRequest

_ORDER = lambda r: (r.arrival_time, r.rid)


class RequestQueue:
    """Arrival-ordered pending pool; the scheduler picks admission order.

    ``max_pending`` bounds the *arrived-but-unadmitted* backlog
    (admission control): a pre-synthesized trace's future arrivals are
    not in the system yet, so they never count against the bound — the
    server calls :meth:`enforce_bound` with its clock each loop, and
    live :meth:`push` enforces it immediately. Victims are always the
    latest arrivals; they collect in :attr:`shed` until a server drains
    them into "shed" results. An unbounded queue (the default) never
    sheds.
    """

    def __init__(self, requests: Sequence[ServeRequest] = (),
                 max_pending: Optional[int] = None):
        self.max_pending = None
        self.shed: List[ServeRequest] = []
        self.shed_count = 0
        self._pending: List[ServeRequest] = sorted(requests, key=_ORDER)
        # conservation counters: every request that ever entered the
        # queue is pending, admitted, or shed — the invariant the
        # recovery watchdog audits live
        self.arrived_total = len(self._pending)
        self.admitted_total = 0
        self.drained_total = 0
        self.set_bound(max_pending)

    def set_bound(self, max_pending: Optional[int]) -> None:
        """(Re)set the admission bound; takes effect at the next
        :meth:`enforce_bound` / :meth:`push`, so a server can tighten it
        at run start without instantly shedding a whole offline trace."""
        assert max_pending is None or max_pending > 0, max_pending
        self.max_pending = max_pending

    def enforce_bound(self, now: float) -> List[ServeRequest]:
        """Shed the latest-arrived ready requests beyond ``max_pending``
        — the backlog a bounded server refuses to let build up."""
        if self.max_pending is None:
            return []
        over = self.ready(now)[self.max_pending:]
        if over:
            # one O(n) pass keyed on identity — `r not in over` would
            # rescan the victim list per pending request (O(n*m))
            drop = {id(r) for r in over}
            self._pending = [r for r in self._pending if id(r) not in drop]
            self._shed(over)
        return over

    def _shed(self, reqs: Sequence[ServeRequest]) -> None:
        self.shed.extend(reqs)
        self.shed_count += len(reqs)

    def push(self, req: ServeRequest) -> bool:
        """Insert in arrival order (stable for out-of-order pushes).
        Returns False when the bound forces a shed — of the latest
        arrival, which may be ``req`` itself."""
        insort(self._pending, req, key=_ORDER)
        self.arrived_total += 1
        if self.max_pending is not None and len(self._pending) > self.max_pending:
            victim = self._pending.pop()
            self._shed([victim])
            return False
        return True

    def drop_expired(self, now: float) -> List[ServeRequest]:
        """Shed every pending request whose SLO deadline has already
        passed — admitting it could only produce a deadline miss."""
        expired = [r for r in self._pending
                   if r.deadline is not None and r.deadline <= now]
        if expired:
            drop = {id(r) for r in expired}
            self._pending = [r for r in self._pending if id(r) not in drop]
            self._shed(expired)
        return expired

    def drain_shed(self) -> List[ServeRequest]:
        """Hand the accumulated shed requests to the caller (once)."""
        out, self.shed = self.shed, []
        self.drained_total += len(out)
        return out

    def ready(self, now: float) -> List[ServeRequest]:
        """Requests that have arrived and are not yet admitted."""
        return [r for r in self._pending if r.arrival_time <= now]

    def pending(self) -> List[ServeRequest]:
        """Snapshot of the pending pool in arrival order (checkpointing
        and journal replay read this; mutation stays internal)."""
        return list(self._pending)

    def admit(self, req: ServeRequest) -> None:
        """Move ``req`` from pending to in-service. Raises ``KeyError``
        when it is not pending — the scheduler raced a shed/expiry (the
        first failure mode journal replay hits), or it was admitted
        twice."""
        try:
            self._pending.remove(req)
        except ValueError:
            raise KeyError(
                f"request rid={req.rid} is not pending (concurrently "
                f"shed/expired, or already admitted)") from None
        self.admitted_total += 1

    def next_arrival(self) -> Optional[float]:
        return self._pending[0].arrival_time if self._pending else None

    def backlog(self, now: float) -> int:
        """Queue depth: arrived but not yet admitted."""
        return len(self.ready(now))

    def __len__(self) -> int:
        return len(self._pending)

    def audit(self) -> List[str]:
        """Internal-consistency check (watchdog contract): returns a
        list of violation strings, empty when healthy."""
        v = []
        accounted = len(self._pending) + self.admitted_total + self.shed_count
        if self.arrived_total != accounted:
            v.append(
                f"queue conservation: arrived_total={self.arrived_total} != "
                f"pending={len(self._pending)} + admitted={self.admitted_total}"
                f" + shed={self.shed_count}")
        if self.shed_count != self.drained_total + len(self.shed):
            v.append(
                f"shed accounting: shed_count={self.shed_count} != "
                f"drained={self.drained_total} + undrained={len(self.shed)}")
        if any(_ORDER(a) > _ORDER(b)
               for a, b in zip(self._pending, self._pending[1:])):
            v.append("pending pool out of arrival order")
        return v


@dataclass(frozen=True)
class TrafficConfig:
    n_requests: int = 16
    arrival: str = "poisson"  # "poisson" | "bursty" | "all_at_once"
    rate: float = 4.0  # mean arrival rate, requests / virtual second
    burst_size: int = 4
    prompt_len: Tuple[int, int] = (8, 32)  # inclusive range
    max_new_tokens: Tuple[int, int] = (4, 32)  # inclusive range
    temperature: float = 0.0
    stop_tokens: Tuple[int, ...] = ()
    n_clusters: Optional[int] = None  # restrict to the first k clusters
    slo: Optional[float] = None  # per-request SLO (virtual s); None = best effort
    quality: float = 1.0  # little-expert quality dial (1.0 = always exact)
    seed: int = 0


def synthesize_workload(lm: ClusterLM, tcfg: TrafficConfig) -> List[ServeRequest]:
    """Sample a request trace over the ClusterLM prompt distribution."""
    rng = np.random.default_rng(tcfg.seed)
    n = tcfg.n_requests

    if tcfg.arrival == "poisson":
        gaps = rng.exponential(1.0 / max(tcfg.rate, 1e-9), n)
        arrivals = np.cumsum(gaps)
    elif tcfg.arrival == "bursty":
        burst_gap = tcfg.burst_size / max(tcfg.rate, 1e-9)
        arrivals = np.asarray([(i // tcfg.burst_size) * burst_gap for i in range(n)])
    elif tcfg.arrival == "all_at_once":
        arrivals = np.zeros(n)
    else:
        raise ValueError(f"unknown arrival process: {tcfg.arrival!r}")

    k_max = tcfg.n_clusters or lm.cfg.n_clusters
    reqs = []
    for i in range(n):
        cluster = int(rng.integers(k_max))
        plen = int(rng.integers(tcfg.prompt_len[0], tcfg.prompt_len[1] + 1))
        seq, _ = lm.sample_sequence(rng, cluster=cluster)
        prompt = seq[:plen].astype(np.int32)
        max_new = int(rng.integers(tcfg.max_new_tokens[0], tcfg.max_new_tokens[1] + 1))
        reqs.append(
            ServeRequest(
                rid=i,
                prompt=prompt,
                max_new_tokens=max_new,
                temperature=tcfg.temperature,
                stop_tokens=tcfg.stop_tokens,
                arrival_time=float(arrivals[i]),
                cluster=cluster,
                slo=tcfg.slo,
                quality=tcfg.quality,
            )
        )
    return reqs
