"""Serving request/result types shared by every scheduler and server.

A ``ServeRequest`` extends the static-batch ``inference.engine.Request``
with the fields a continuous-batching server needs: an identity, an
arrival time on the (virtual) serving clock, per-request stop tokens,
and the optional predictor-scored expert preferences that the
expert-affinity scheduler groups on (paper Sec 3.1.2 / Eq. 7).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np


@dataclass(eq=False)  # identity semantics: the ndarray prompt makes the
class ServeRequest:   # generated __eq__ crash in list.remove / comparisons
    rid: int
    prompt: np.ndarray  # (T,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    stop_tokens: Tuple[int, ...] = ()
    arrival_time: float = 0.0
    cluster: Optional[int] = None  # latent workload cluster (telemetry only)
    expert_scores: Optional[np.ndarray] = None  # (L, E) predictor scores
    # SLO: virtual seconds after arrival by which the request must finish;
    # None = best effort (never shed, never deadline-retired)
    slo: Optional[float] = None
    # quality-vs-latency dial for the little-expert degraded mode:
    # fraction of cache misses served by the big (exact) expert. 1.0 =
    # always exact; 0.0 = always the low-rank distillate. Only honored
    # by engines built with a little bank.
    quality: float = 1.0
    # crash-recovery watermark: tokens this request had already emitted
    # before the process died (journal replay sets it). A server admits
    # a resumed request by prefilling concat(prompt, resumed) — greedy
    # decode depends only on the token prefix, so generation continues
    # token-identically — and counts them against max_new_tokens.
    resumed: Optional[np.ndarray] = None  # (n,) int32 or None

    @property
    def n_resumed(self) -> int:
        return 0 if self.resumed is None else int(len(self.resumed))

    @property
    def deadline(self) -> Optional[float]:
        """Absolute virtual-clock deadline, or None when best-effort."""
        return None if self.slo is None else self.arrival_time + self.slo

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))

    @property
    def job_size(self) -> int:
        """Total token work estimate (prefill + decode budget)."""
        return self.prompt_len + int(self.max_new_tokens)

    def expert_set(self, top_c: int) -> frozenset:
        """Predicted Top-C expert ids per layer as {(layer, expert)} —
        the overlap currency of the affinity scheduler. Empty set when
        the request carries no scores."""
        if self.expert_scores is None:
            return frozenset()
        top = np.argsort(-np.asarray(self.expert_scores), axis=-1)[:, :top_c]
        return frozenset(
            (int(l), int(e)) for l in range(top.shape[0]) for e in top[l]
        )


@dataclass(eq=False)  # same: tokens is an ndarray
class ServeResult:
    rid: int
    tokens: np.ndarray  # (<= max_new_tokens,) int32 generated tokens
    # "stop" | "length" | "deadline" (cut mid-decode at the SLO) |
    # "shed" (never admitted: queue bound or expired while waiting)
    finish_reason: str
    arrival_time: float = 0.0
    start_time: float = 0.0
    finish_time: float = 0.0
    decode_steps: int = 0  # batch decode iterations this request was live for
    degraded: bool = False  # served >=1 little-expert substitution

    @property
    def latency(self) -> float:
        return self.finish_time - self.arrival_time

    @property
    def queue_delay(self) -> float:
        return self.start_time - self.arrival_time
