"""Self-contained AdamW + linear-warmup/linear-decay schedule
(paper Table 7: AdamW, linear schedule, warmup ratio 0.03)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 1e-5  # paper Table 7
    total_steps: int = 1000
    warmup_ratio: float = 0.03
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: Optional[float] = 1.0
    min_lr_frac: float = 0.0


def schedule(step, cfg: OptConfig):
    warm = max(int(cfg.total_steps * cfg.warmup_ratio), 1)
    s = step.astype(jnp.float32)
    lr_warm = cfg.peak_lr * s / warm
    frac = jnp.clip((s - warm) / max(cfg.total_steps - warm, 1), 0.0, 1.0)
    lr_dec = cfg.peak_lr * (1.0 - (1.0 - cfg.min_lr_frac) * frac)
    return jnp.where(s < warm, lr_warm, lr_dec)


def init_opt_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(grads, opt_state, params, cfg: OptConfig, mask=None):
    """One AdamW step. ``mask``: bool pytree — False leaves are frozen."""
    step = opt_state["step"] + 1
    lr = schedule(step, cfg)
    if cfg.clip_norm is not None:
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    b1, b2 = cfg.b1, cfg.b2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      opt_state["mu"], grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                      opt_state["nu"], grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if cfg.weight_decay:
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    if mask is not None:
        new_params = jax.tree.map(
            lambda old, new, m: new if m else old, params, new_params, mask
        )
        mu = jax.tree.map(lambda m_, msk: m_ if msk else jnp.zeros_like(m_), mu, mask)
        nu = jax.tree.map(lambda v_, msk: v_ if msk else jnp.zeros_like(v_), nu, mask)
    return new_params, {"mu": mu, "nu": nu, "step": step}, {"lr": lr}
