"""Training loops: pretraining a base MoE on the synthetic corpus, and
the MELINOE fine-tuning stage (Sec 3.1). CPU-scale driver used by the
examples and the paper-claim benchmarks; the production path is
launch/train.py + pjit."""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.lora import (
    extract_base_routers,
    init_lora,
    lora_scale,
    melinoe_trainable_mask,
)
from ..launch.steps import build_finetune_step, build_train_step
from ..models.model import init_params
from ..models.runtime import Runtime
from .optim import OptConfig, adamw_update, init_opt_state


@dataclass
class TrainResult:
    params: dict
    history: List[Dict[str, float]] = field(default_factory=list)
    lora: Optional[dict] = None

    def last(self, key: str) -> float:
        return self.history[-1][key]


def pretrain(
    cfg: ModelConfig,
    data_iter,
    *,
    steps: int,
    opt_cfg: Optional[OptConfig] = None,
    rt: Optional[Runtime] = None,
    seed: int = 0,
    melinoe_aux: bool = False,
    log_every: int = 50,
    params: Optional[dict] = None,
    verbose: bool = True,
) -> TrainResult:
    """Standard LM pretraining (NLL only by default): builds the *base*
    model whose weak per-sequence expert preferences MELINOE amplifies."""
    rt = rt or Runtime()
    opt_cfg = opt_cfg or OptConfig(peak_lr=3e-3, total_steps=steps, weight_decay=0.01)
    if params is None:
        params = init_params(jax.random.key(seed), cfg, jnp.float32)
    opt_state = init_opt_state(params)
    step_fn = jax.jit(build_train_step(cfg, rt, opt_cfg, melinoe=melinoe_aux),
                      donate_argnums=(0, 1))
    history = []
    t0 = time.time()
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in next(data_iter).items() if k != "cluster"}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if i % log_every == 0 or i == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = i
            m["time"] = time.time() - t0
            history.append(m)
            if verbose:
                print(f"[pretrain {i:5d}] " + " ".join(f"{k}={v:.4f}" for k, v in m.items()))
    return TrainResult(params=params, history=history)


def melinoe_finetune(
    cfg: ModelConfig,
    base_params,
    data_iter,
    *,
    steps: int,
    opt_cfg: Optional[OptConfig] = None,
    rt: Optional[Runtime] = None,
    seed: int = 0,
    log_every: int = 50,
    verbose: bool = True,
) -> TrainResult:
    """Pre-deployment stage (Sec 3.1.1): router + expert gate full update,
    LoRA on expert up/down, L = L_nll + l_cs L_cs + l_rm L_rm."""
    assert cfg.melinoe is not None and cfg.has_router
    rt = rt or Runtime()
    # smoke-scale default aligned with pretrain (3e-3): the partition is
    # tiny (router + gate + LoRA) and short runs must move it far enough
    # that routing concentration beats batch noise; keep a non-zero floor
    # so the last steps of a short schedule still learn
    opt_cfg = opt_cfg or OptConfig(peak_lr=3e-3, total_steps=steps,
                                   min_lr_frac=0.1)
    # real copies: `params` is donated by the jitted step, and the frozen
    # base_routers must keep their own buffers
    params = jax.tree.map(jnp.copy, base_params)
    lora = init_lora(jax.random.key(seed + 1), cfg, cfg.melinoe)
    mask = melinoe_trainable_mask(params)
    base_routers = jax.tree.map(jnp.copy, extract_base_routers(base_params, cfg))
    opt_state = init_opt_state((params, lora))
    step_fn = jax.jit(build_finetune_step(cfg, rt, opt_cfg, mask),
                      donate_argnums=(0, 1, 2))
    history = []
    t0 = time.time()
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in next(data_iter).items() if k != "cluster"}
        params, lora, opt_state, metrics = step_fn(
            params, lora, opt_state, batch, base_routers
        )
        if i % log_every == 0 or i == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = i
            m["time"] = time.time() - t0
            history.append(m)
            if verbose:
                print(f"[melinoe {i:5d}] " + " ".join(f"{k}={v:.4f}" for k, v in m.items()))
    return TrainResult(params=params, history=history, lora=lora)


def merge_lora(cfg: ModelConfig, params, lora, scale: float):
    """Bake LoRA deltas into the expert weights (deployment checkpoint)."""
    out = jax.tree.map(lambda a: a, params)
    for gi, g in enumerate(cfg.layout):
        gname = f"g{gi}"
        for pi, bname in enumerate(g.pattern):
            if cfg.block_defs[bname].moe is None or f"p{pi}" not in lora.get(gname, {}):
                continue
            ffn = out["groups"][gname][f"p{pi}"]["ffn"]
            lt = lora[gname][f"p{pi}"]
            for t in ("wu", "wd"):
                delta = jnp.einsum("redk,rekf->redf", lt[t]["a"], lt[t]["b"])
                ffn[t] = ffn[t] + (scale * delta).astype(ffn[t].dtype)
    return out


def eval_nll(cfg: ModelConfig, params, batches, rt: Optional[Runtime] = None,
             lora=None, scale: float = 1.0) -> float:
    from ..launch.steps import make_loss_fn

    rt = rt or Runtime()

    @jax.jit
    def f(p, batch):
        from ..models.model import apply_model
        logits, _ = apply_model(p, cfg, batch["tokens"], rt, lora=lora, lora_scale=scale)
        pred = logits[:, :-1]
        tgt = batch["labels"][:, 1:]
        from ..core.losses import nll_loss
        return nll_loss(pred, tgt)

    vals = [float(f(params, {k: jnp.asarray(v) for k, v in b.items() if k != "cluster"}))
            for b in batches]
    return float(np.mean(vals))
