"""Msgpack + raw-numpy checkpointing (self-contained; no orbax offline)."""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(path, tree, *, step: int = 0, metadata: dict | None = None):
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    leaves, treedef = _flatten(tree)
    payload = {
        "step": step,
        "metadata": metadata or {},
        "treedef": str(treedef),
        "leaves": [
            {
                "dtype": str(np.asarray(l).dtype),
                "shape": list(np.asarray(l).shape),
                "data": np.ascontiguousarray(np.asarray(l)).tobytes(),
            }
            for l in leaves
        ],
    }
    path.write_bytes(msgpack.packb(payload, use_bin_type=True))


def load_checkpoint(path, like_tree):
    """Restore into the structure of ``like_tree`` (shapes must match)."""
    payload = msgpack.unpackb(Path(path).read_bytes(), raw=False)
    leaves_like, treedef = jax.tree_util.tree_flatten(like_tree)
    stored = payload["leaves"]
    assert len(stored) == len(leaves_like), (
        f"leaf count mismatch: {len(stored)} vs {len(leaves_like)}"
    )
    leaves = []
    for rec, like in zip(stored, leaves_like):
        arr = np.frombuffer(rec["data"], dtype=np.dtype(rec["dtype"])).reshape(rec["shape"])
        # `like` may be a concrete array OR a ShapeDtypeStruct template
        assert tuple(arr.shape) == tuple(like.shape), (arr.shape, like.shape)
        leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), payload["step"], payload["metadata"]
