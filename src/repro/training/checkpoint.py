"""Msgpack + raw-numpy checkpointing (self-contained; no orbax offline).

Array leaves are encoded with the shared ``recovery.serial`` records
(the same helper behind the server snapshots and the request journal),
and the payload lands via an atomic temp-file + rename so a crash
mid-save never truncates the previous checkpoint.
"""
from __future__ import annotations

from pathlib import Path
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import msgpack

from ..recovery.serial import array_record, atomic_write_bytes, record_array


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(path, tree, *, step: int = 0, metadata: dict | None = None):
    path = Path(path)
    leaves, treedef = _flatten(tree)
    payload = {
        "step": step,
        "metadata": metadata or {},
        "treedef": str(treedef),
        "leaves": [array_record(l, binary=True) for l in leaves],
    }
    atomic_write_bytes(path, msgpack.packb(payload, use_bin_type=True))


def load_checkpoint(path, like_tree):
    """Restore into the structure of ``like_tree`` (shapes must match)."""
    payload = msgpack.unpackb(Path(path).read_bytes(), raw=False)
    leaves_like, treedef = jax.tree_util.tree_flatten(like_tree)
    stored = payload["leaves"]
    assert len(stored) == len(leaves_like), (
        f"leaf count mismatch: {len(stored)} vs {len(leaves_like)}"
    )
    leaves = []
    for rec, like in zip(stored, leaves_like):
        arr = record_array(rec)
        # `like` may be a concrete array OR a ShapeDtypeStruct template
        assert tuple(arr.shape) == tuple(like.shape), (arr.shape, like.shape)
        leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), payload["step"], payload["metadata"]
