"""Fault injection + resilience primitives for the offloaded serving
stack.

Layers:
  plan.py   — seedable deterministic :class:`FaultPlan` (env/config
              driven, NullTracer-style zero cost when disabled) injecting
              transfer latency spikes, transient fetch failures, eviction
              storms, server clock stalls, and traffic bursts
  retry.py  — :class:`FetchPolicy`: per-fetch deadline with bounded
              exponential-backoff retries for the engine's host-transfer
              seam
"""
from .plan import (
    NULL_FAULT_PLAN,
    FaultConfig,
    FaultPlan,
    InjectedCrash,
    NullFaultPlan,
    fault_plan_from_env,
    get_fault_plan,
    install_fault_plan,
    parse_fault_spec,
    uninstall_fault_plan,
)
from .retry import NAIVE_POLICY, FetchPolicy

__all__ = [
    "FaultConfig",
    "FaultPlan",
    "InjectedCrash",
    "NullFaultPlan",
    "NULL_FAULT_PLAN",
    "FetchPolicy",
    "NAIVE_POLICY",
    "get_fault_plan",
    "install_fault_plan",
    "uninstall_fault_plan",
    "parse_fault_spec",
    "fault_plan_from_env",
]
