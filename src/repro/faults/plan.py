"""Seedable, deterministic fault injection for the offloaded stack.

MELINOE's premise is that the expert transfer is the fragile resource:
a production deployment sees DMA latency spikes, transient fetch
failures, cache-thrashing interference, and traffic bursts ("Towards
MoE Deployment", Huang et al.). A :class:`FaultPlan` injects exactly
those events at the engine's host-transfer seam
(``OffloadedMoEEngine._fetch`` / ``_ensure_resident``) and at the
servers' virtual clocks, so the resilience layer (retry/backoff,
little-expert degraded mode, SLO shedding) can be exercised and
benchmarked deterministically.

Design mirrors ``obs.trace``:

* **Zero cost when disabled.** The module global defaults to
  :data:`NULL_FAULT_PLAN`; hot paths guard on ``plan.enabled`` (one
  attribute load) and never construct arguments for a disabled plan.
* **Deterministic.** All draws come from one ``np.random.Generator``
  seeded by the config; the same plan over the same call sequence
  replays the same faults, so chaos benchmarks race configurations
  under the *identical* fault trace.
* **Env-driven.** ``REPRO_FAULTS="fail=0.1,spike=0.05:2e-3,seed=7"``
  installs a plan at import time for any entry point; ``rate:magnitude``
  pairs are colon-separated.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, replace
from typing import Dict, List, Optional

import numpy as np

from ..obs.trace import get_tracer


@dataclass(frozen=True)
class FaultConfig:
    """Intensities for each injected fault class (all default off)."""

    seed: int = 0
    # transient host->device fetch failures: each transfer attempt fails
    # with this probability (retried by the engine's FetchPolicy)
    fetch_fail_rate: float = 0.0
    # transfer latency spikes: each successful transfer is delayed by
    # spike_s extra modeled seconds with probability spike_rate
    spike_rate: float = 0.0
    spike_s: float = 0.0
    # eviction storms: once per engine step with probability storm_rate,
    # a storm_frac fraction of every layer's residents is evicted
    # (interference from a co-tenant thrashing device memory)
    storm_rate: float = 0.0
    storm_frac: float = 0.5
    # server clock stalls: each decode step is delayed by step_delay_s
    # virtual seconds with probability step_delay_rate (GC pause /
    # noisy-neighbor interference on the serving host)
    step_delay_rate: float = 0.0
    step_delay_s: float = 0.0
    # traffic bursts: compress_arrivals squeezes each request's arrival
    # toward the start of its burst window by this fraction (0 = leave
    # the trace alone, 1 = every window collapses to a simultaneous
    # burst), over windows of burst_window requests
    burst_compress: float = 0.0
    burst_window: int = 8
    # process crashes: each crash point (one per decode step in the
    # engine and the continuous server) raises InjectedCrash with
    # probability crash_rate, or deterministically on exactly the
    # crash_at-th point (1-based; 0 = off) — the kill half of the
    # kill -> restore -> replay chaos loop
    crash_rate: float = 0.0
    crash_at: int = 0
    # worker-level faults for the fleet supervisor. kill: a hard
    # process exit mid-step (os._exit — no unwinding, no journal close,
    # indistinguishable from SIGKILL), with probability kill_rate per
    # worker step hook or deterministically at the kill_at-th hook.
    # hang: the worker goes silent (no heartbeat, no progress) for
    # hang_s wall seconds while the process stays alive, so only the
    # supervisor's heartbeat-staleness deadline — not process exit —
    # can detect it.
    kill_rate: float = 0.0
    kill_at: int = 0
    hang_rate: float = 0.0
    hang_s: float = 0.0
    hang_at: int = 0

    @property
    def any_active(self) -> bool:
        return any(r > 0 for r in (
            self.fetch_fail_rate, self.spike_rate, self.storm_rate,
            self.step_delay_rate, self.burst_compress, self.crash_rate,
            self.crash_at, self.kill_rate, self.kill_at, self.hang_rate,
            self.hang_at))


_SPEC_KEYS = {
    "seed": ("seed",),
    "fail": ("fetch_fail_rate",),
    "spike": ("spike_rate", "spike_s"),
    "storm": ("storm_rate", "storm_frac"),
    "step_delay": ("step_delay_rate", "step_delay_s"),
    "burst": ("burst_compress", "burst_window"),
    "crash": ("crash_rate",),
    "crash_at": ("crash_at",),
    "kill": ("kill_rate",),
    "kill_at": ("kill_at",),
    "hang": ("hang_rate", "hang_s"),
    "hang_at": ("hang_at", "hang_s"),
}


class InjectedCrash(RuntimeError):
    """Simulated process death raised at a fault-plan crash point. The
    serving stack deliberately does NOT catch it — it unwinds like a
    kill so recovery tests exercise the journal/restore path for real."""


def parse_fault_spec(spec: str) -> FaultConfig:
    """``"fail=0.1,spike=0.05:2e-3,storm=0.02:0.5,seed=7"`` ->
    :class:`FaultConfig`. Unknown keys raise so typos never silently
    disable a chaos run."""
    cfg = FaultConfig()
    for item in spec.split(","):
        item = item.strip()
        if not item:
            continue
        key, _, val = item.partition("=")
        key = key.strip()
        if key not in _SPEC_KEYS:
            raise ValueError(
                f"unknown fault key {key!r}; options: {sorted(_SPEC_KEYS)}")
        fields = _SPEC_KEYS[key]
        parts = val.split(":")
        if len(parts) > len(fields):
            raise ValueError(f"too many values for {key!r}: {val!r}")
        updates = {}
        for f, p in zip(fields, parts):
            cur = getattr(cfg, f)
            updates[f] = type(cur)(float(p)) if isinstance(cur, int) \
                else float(p)
        cfg = replace(cfg, **updates)
    return cfg


class FaultPlan:
    """Live fault injector. Every draw is counted (``counters``) and,
    when tracing is enabled, emitted as a ``fault.*`` instant so chaos
    traces show where each injected event landed."""

    enabled = True

    def __init__(self, cfg: FaultConfig):
        self.cfg = cfg
        self._rng = np.random.default_rng(cfg.seed)
        self.counters: Dict[str, int] = {
            "fetch_fail": 0, "spike": 0, "storm": 0, "step_delay": 0,
            "crash": 0, "kill": 0, "hang": 0,
        }
        self._crash_calls = 0
        self._kill_calls = 0
        self._hang_calls = 0

    # -- draws (one per potential event; deterministic in call order) ----
    def fetch_fails(self, moe_idx: int = -1) -> bool:
        """One host->device transfer attempt: does it transiently fail?"""
        c = self.cfg
        if c.fetch_fail_rate <= 0.0:
            return False
        if self._rng.random() >= c.fetch_fail_rate:
            return False
        self.counters["fetch_fail"] += 1
        tr = get_tracer()
        if tr.enabled:
            tr.instant("fault.fetch_fail", layer=moe_idx)
        return True

    def transfer_spike(self, moe_idx: int = -1) -> float:
        """Extra modeled seconds of DMA latency for one transfer."""
        c = self.cfg
        if c.spike_rate <= 0.0 or self._rng.random() >= c.spike_rate:
            return 0.0
        self.counters["spike"] += 1
        tr = get_tracer()
        if tr.enabled:
            tr.instant("fault.spike", layer=moe_idx, extra_s=c.spike_s)
        return c.spike_s

    def eviction_storm(self) -> float:
        """Per engine step: fraction of residents to evict (0 = calm)."""
        c = self.cfg
        if c.storm_rate <= 0.0 or self._rng.random() >= c.storm_rate:
            return 0.0
        self.counters["storm"] += 1
        tr = get_tracer()
        if tr.enabled:
            tr.instant("fault.storm", frac=c.storm_frac)
        return c.storm_frac

    def storm_victims(self, residents, frac: float) -> List[int]:
        """Deterministic victim pick for one layer of an eviction storm."""
        residents = sorted(residents)
        k = int(round(len(residents) * frac))
        if k <= 0:
            return []
        pick = self._rng.choice(len(residents), size=k, replace=False)
        return [residents[i] for i in pick]

    def step_delay(self) -> float:
        """Extra virtual seconds injected into one server decode step."""
        c = self.cfg
        if c.step_delay_rate <= 0.0 or self._rng.random() >= c.step_delay_rate:
            return 0.0
        self.counters["step_delay"] += 1
        tr = get_tracer()
        if tr.enabled:
            tr.instant("fault.step_delay", extra_s=c.step_delay_s)
        return c.step_delay_s

    def maybe_crash(self, where: str = "") -> None:
        """One crash point. Raises :class:`InjectedCrash` on the
        ``crash_at``-th call (deterministic kill) or with probability
        ``crash_rate`` (random kills for the sweep); otherwise a no-op.
        Call points are counted across engine and server alike, so
        ``crash_at=K`` lands at the same spot on every identical run."""
        c = self.cfg
        if c.crash_at <= 0 and c.crash_rate <= 0.0:
            return
        self._crash_calls += 1
        hit = self._crash_calls == c.crash_at
        if not hit and c.crash_rate > 0.0:
            hit = self._rng.random() < c.crash_rate
        if not hit:
            return
        self.counters["crash"] += 1
        tr = get_tracer()
        if tr.enabled:
            tr.instant("fault.crash", call=self._crash_calls, where=where)
        raise InjectedCrash(
            f"injected crash at point {self._crash_calls}"
            + (f" ({where})" if where else ""))

    def maybe_kill(self, where: str = "") -> bool:
        """One worker kill point (the fleet worker's step hook). Returns
        True when the process must hard-exit NOW; the caller performs
        the ``os._exit`` so unit tests can observe the verdict without
        dying. Counted separately from crash points, and short-circuited
        before any rng draw when off, so a pure worker-fault spec never
        perturbs the engine-fault stream."""
        c = self.cfg
        if c.kill_at <= 0 and c.kill_rate <= 0.0:
            return False
        self._kill_calls += 1
        hit = self._kill_calls == c.kill_at
        if not hit and c.kill_rate > 0.0:
            hit = self._rng.random() < c.kill_rate
        if not hit:
            return False
        self.counters["kill"] += 1
        tr = get_tracer()
        if tr.enabled:
            tr.instant("fault.kill", call=self._kill_calls, where=where)
        return True

    def maybe_hang(self) -> float:
        """Wall seconds the worker should go silent at this step hook
        (no heartbeat, no progress — the process stays alive). 0.0 =
        keep running. The hang is what distinguishes the supervisor's
        staleness detector from plain exit-code watching."""
        c = self.cfg
        if c.hang_at <= 0 and c.hang_rate <= 0.0:
            return 0.0
        self._hang_calls += 1
        hit = self._hang_calls == c.hang_at
        if not hit and c.hang_rate > 0.0:
            hit = self._rng.random() < c.hang_rate
        if not hit:
            return 0.0
        self.counters["hang"] += 1
        tr = get_tracer()
        if tr.enabled:
            tr.instant("fault.hang", call=self._hang_calls, hang_s=c.hang_s)
        return c.hang_s

    # -- workload shaping ------------------------------------------------
    def compress_arrivals(self, requests) -> None:
        """Traffic bursts: within each window of ``burst_window``
        consecutive requests, pull every arrival toward the window's
        first arrival by ``burst_compress`` (in place, order preserved —
        arrivals within a window share a start, so compression never
        reorders the trace)."""
        c = self.cfg
        if c.burst_compress <= 0.0:
            return
        reqs = sorted(requests, key=lambda r: (r.arrival_time, r.rid))
        for i in range(0, len(reqs), max(c.burst_window, 1)):
            window = reqs[i:i + max(c.burst_window, 1)]
            t0 = window[0].arrival_time
            for r in window:
                r.arrival_time = t0 + (r.arrival_time - t0) * (
                    1.0 - c.burst_compress)

    # -- obs -------------------------------------------------------------
    def publish(self, registry=None) -> None:
        """Export injected-event counts as ``fault_injected_total``
        gauges labeled by kind (global registry by default)."""
        if registry is None:
            from ..obs.registry import REGISTRY as registry
        for kind, n in self.counters.items():
            registry.gauge("fault_injected_total",
                           "events injected by the active FaultPlan",
                           kind=kind).set(n)


class NullFaultPlan:
    """Disabled injection: every hook is a no-op returning the benign
    value. ``enabled`` is a class attribute, so the hot-path guard is a
    single attribute load (NullTracer-style)."""

    enabled = False
    cfg = FaultConfig()
    counters: Dict[str, int] = {}

    def fetch_fails(self, moe_idx: int = -1) -> bool:
        return False

    def transfer_spike(self, moe_idx: int = -1) -> float:
        return 0.0

    def eviction_storm(self) -> float:
        return 0.0

    def storm_victims(self, residents, frac: float) -> List[int]:
        return []

    def step_delay(self) -> float:
        return 0.0

    def maybe_crash(self, where: str = "") -> None:
        pass

    def maybe_kill(self, where: str = "") -> bool:
        return False

    def maybe_hang(self) -> float:
        return 0.0

    def compress_arrivals(self, requests) -> None:
        pass

    def publish(self, registry=None) -> None:
        pass


NULL_FAULT_PLAN = NullFaultPlan()
_plan = NULL_FAULT_PLAN

ENV_VAR = "REPRO_FAULTS"


def get_fault_plan():
    """The active plan — :data:`NULL_FAULT_PLAN` unless one was
    installed. Hot paths hold the result once per step and guard bulk
    work on ``.enabled``."""
    return _plan


def install_fault_plan(cfg_or_spec) -> FaultPlan:
    """Install (and return) a fresh :class:`FaultPlan` as the global.
    Accepts a :class:`FaultConfig` or a spec string."""
    global _plan
    cfg = (parse_fault_spec(cfg_or_spec)
           if isinstance(cfg_or_spec, str) else cfg_or_spec)
    _plan = FaultPlan(cfg)
    return _plan


def uninstall_fault_plan() -> None:
    global _plan
    _plan = NULL_FAULT_PLAN


def fault_plan_from_env() -> Optional[FaultPlan]:
    """Install a plan from ``REPRO_FAULTS`` if set (any entry point)."""
    spec = os.environ.get(ENV_VAR)
    if not spec:
        return None
    return install_fault_plan(spec)


fault_plan_from_env()
