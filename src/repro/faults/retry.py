"""Retry/backoff policy for the engine's host-transfer seam.

A fetch attempt that the :class:`~repro.faults.plan.FaultPlan` fails is
retried under a :class:`FetchPolicy`: bounded attempts with exponential
backoff, all charged to the *modeled* clock (the failed DMA burned real
link time; the backoff is deliberate idle). When retries or the
per-fetch deadline are exhausted the engine degrades to the little
expert instead of raising — unless no little bank exists, in which case
it keeps retrying (the "no-resilience baseline" the chaos benchmark
measures against), up to ``hard_cap`` as a runaway guard.
"""
from __future__ import annotations

from dataclasses import dataclass

_MASK64 = 0xFFFFFFFFFFFFFFFF


def _unit_hash(*keys: int) -> float:
    """Deterministic stateless hash of integer keys onto [0, 1).
    splitmix64-style mixing: stable across processes and Python runs
    (unlike ``hash``), with no Generator state to thread through the
    frozen policy."""
    h = 0x9E3779B97F4A7C15
    for k in keys:
        h ^= ((int(k) & _MASK64) + 0x9E3779B97F4A7C15
              + ((h << 6) & _MASK64) + (h >> 2)) & _MASK64
        h &= _MASK64
    h ^= h >> 30
    h = (h * 0xBF58476D1CE4E5B9) & _MASK64
    h ^= h >> 27
    h = (h * 0x94D049BB133111EB) & _MASK64
    h ^= h >> 31
    return h / 2.0 ** 64


@dataclass(frozen=True)
class FetchPolicy:
    """Per-expert-fetch retry budget.

    ``max_retries < 0`` means unbounded (still capped at ``hard_cap``
    attempts as a safety net against a 100%-failure plan wedging the
    no-degrade baseline forever).
    """

    max_retries: int = 3
    backoff_base_s: float = 1e-4
    backoff_mult: float = 2.0
    backoff_cap_s: float = 5e-3
    # give up on a single expert fetch once its attempts have consumed
    # this much modeled time (None = no per-fetch deadline)
    fetch_deadline_s: float | None = None
    hard_cap: int = 1000
    # deterministic seeded jitter: each backoff is scaled by a factor
    # drawn from [1 - jitter_frac, 1], keyed on (seed, salt, attempt).
    # N restarted workers that pass distinct salts (their worker index)
    # decorrelate instead of retrying in lockstep after a shared
    # failure. 0.0 (the default, and NAIVE_POLICY) = exact exponential.
    jitter_frac: float = 0.0
    seed: int = 0

    def backoff(self, attempt: int, salt: int = 0) -> float:
        """Modeled idle seconds before retry ``attempt`` (0-based)."""
        base = min(self.backoff_base_s * (self.backoff_mult ** attempt),
                   self.backoff_cap_s)
        if self.jitter_frac <= 0.0 or base <= 0.0:
            return base
        u = _unit_hash(self.seed, salt, attempt)
        return base * (1.0 - self.jitter_frac * u)

    def attempts_allowed(self, attempt: int, spent_s: float) -> bool:
        """May we make attempt number ``attempt`` (0-based) after having
        spent ``spent_s`` modeled seconds on this fetch so far?"""
        if attempt >= self.hard_cap:
            return False
        if self.max_retries >= 0 and attempt > self.max_retries:
            return False
        if self.fetch_deadline_s is not None and spent_s >= self.fetch_deadline_s:
            return False
        return True


NAIVE_POLICY = FetchPolicy(max_retries=-1, backoff_base_s=0.0,
                           backoff_mult=1.0, backoff_cap_s=0.0,
                           fetch_deadline_s=None)
