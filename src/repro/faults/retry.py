"""Retry/backoff policy for the engine's host-transfer seam.

A fetch attempt that the :class:`~repro.faults.plan.FaultPlan` fails is
retried under a :class:`FetchPolicy`: bounded attempts with exponential
backoff, all charged to the *modeled* clock (the failed DMA burned real
link time; the backoff is deliberate idle). When retries or the
per-fetch deadline are exhausted the engine degrades to the little
expert instead of raising — unless no little bank exists, in which case
it keeps retrying (the "no-resilience baseline" the chaos benchmark
measures against), up to ``hard_cap`` as a runaway guard.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class FetchPolicy:
    """Per-expert-fetch retry budget.

    ``max_retries < 0`` means unbounded (still capped at ``hard_cap``
    attempts as a safety net against a 100%-failure plan wedging the
    no-degrade baseline forever).
    """

    max_retries: int = 3
    backoff_base_s: float = 1e-4
    backoff_mult: float = 2.0
    backoff_cap_s: float = 5e-3
    # give up on a single expert fetch once its attempts have consumed
    # this much modeled time (None = no per-fetch deadline)
    fetch_deadline_s: float | None = None
    hard_cap: int = 1000

    def backoff(self, attempt: int) -> float:
        """Modeled idle seconds before retry ``attempt`` (0-based)."""
        return min(self.backoff_base_s * (self.backoff_mult ** attempt),
                   self.backoff_cap_s)

    def attempts_allowed(self, attempt: int, spent_s: float) -> bool:
        """May we make attempt number ``attempt`` (0-based) after having
        spent ``spent_s`` modeled seconds on this fetch so far?"""
        if attempt >= self.hard_cap:
            return False
        if self.max_retries >= 0 and attempt > self.max_retries:
            return False
        if self.fetch_deadline_s is not None and spent_s >= self.fetch_deadline_s:
            return False
        return True


NAIVE_POLICY = FetchPolicy(max_retries=-1, backoff_base_s=0.0,
                           backoff_mult=1.0, backoff_cap_s=0.0,
                           fetch_deadline_s=None)
