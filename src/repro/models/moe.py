"""Sparsely-gated MoE layer (Eq. 1-2) with capacity-based dispatch and
shard_map expert parallelism.

Two execution paths share the dispatch logic:
  * local   — single device (smoke tests, offload engine, oracle)
  * sharded — shard_map over the mesh: tokens sharded on ("pod","data"),
              experts on "model"; two ``lax.all_to_all`` per layer
              (dispatch + return), grouped expert FFN in between.

Dispatch is GShard-style: per-expert capacity ``cap``; overflow tokens
are dropped (gate mass zeroed). ``zero_drop=True`` (decode) sizes the
buffer at N tokens so nothing can drop.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..configs.base import MoESpec
from .common import dense_init, silu
from .mlp import apply_mlp, init_mlp
from .runtime import Runtime

import os as _os

# §Perf optimization (EXPERIMENTS.md, granite/deepseek hillclimbs):
# baseline dispatch shards tokens over the data axes only, so the ms
# model-peers within a data row each dispatch IDENTICAL token buffers —
# the all_to_all and the expert FFN then do ms-times redundant work.
# With the flag on, tokens are sharded over ("data"..., "model") for the
# dispatch, cutting expert FLOPs and all-to-all bytes by ms at the price
# of one (N_loc, d_model) all-gather when resharding the combined output.
_OPT_MOE_DISPATCH_SHARD = "moe_dispatch_shard" in _os.environ.get("REPRO_OPT", "")


def set_opt_flags(**kw):
    g = globals()
    for k, v in kw.items():
        key = "_OPT_" + k.upper()
        assert key in g, key
        g[key] = v


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_moe(key, d_model: int, spec: MoESpec, dtype):
    ks = jax.random.split(key, 5)
    E, f = spec.num_experts, spec.d_ff
    p = {
        "router": dense_init(ks[0], d_model, E, jnp.float32),
        "wg": jax.vmap(lambda k: dense_init(k, d_model, f, dtype))(
            jax.random.split(ks[1], E)
        ),
        "wu": jax.vmap(lambda k: dense_init(k, d_model, f, dtype))(
            jax.random.split(ks[2], E)
        ),
        "wd": jax.vmap(lambda k: dense_init(k, f, d_model, dtype))(
            jax.random.split(ks[3], E)
        ),
    }
    if spec.shared_d_ff:
        p["shared"] = init_mlp(ks[4], d_model, spec.shared_d_ff, dtype)
    return p


# ---------------------------------------------------------------------------
# Routing
# ---------------------------------------------------------------------------


def router_probs(params, x, spec: MoESpec):
    """x: (..., d) -> softmax router distribution (..., E) in fp32 (Eq. 1)."""
    logits = x.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    if spec.router_softcap is not None:
        logits = jnp.tanh(logits / spec.router_softcap) * spec.router_softcap
    return jax.nn.softmax(logits, axis=-1)


def top_k_route(probs, k: int):
    """probs (N, E) -> gates (N, K) raw probabilities, eids (N, K)."""
    gates, eids = lax.top_k(probs, k)
    return gates, eids.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Capacity dispatch
# ---------------------------------------------------------------------------


class Dispatch(NamedTuple):
    eids: jax.Array  # (N, K) int32, == E where dropped
    pos: jax.Array  # (N, K) int32 slot within expert buffer
    gates: jax.Array  # (N, K) f32, zeroed where dropped
    cap: int


def make_dispatch(gates, eids, spec: MoESpec, cap: int) -> Dispatch:
    N, K = eids.shape
    E = spec.num_experts
    flat = eids.reshape(N * K)
    oh = jax.nn.one_hot(flat, E, dtype=jnp.int32)
    pos = (jnp.cumsum(oh, axis=0) * oh).sum(-1) - 1  # occurrences before self
    pos = pos.reshape(N, K)
    keep = pos < cap
    return Dispatch(
        eids=jnp.where(keep, eids, E),  # E = out-of-range sentinel -> scatter drop
        pos=jnp.where(keep, pos, 0),
        gates=jnp.where(keep, gates, 0.0),
        cap=cap,
    )


def dispatch_tokens(d: Dispatch, x, n_experts: int):
    """x (N, dm) -> expert buffers (E, cap, dm)."""
    N, K = d.eids.shape
    xr = jnp.repeat(x[:, None], K, axis=1).reshape(N * K, -1)
    buf = jnp.zeros((n_experts, d.cap, x.shape[-1]), x.dtype)
    return buf.at[d.eids.reshape(-1), d.pos.reshape(-1)].set(xr, mode="drop")


def combine_tokens(d: Dispatch, buf):
    """buf (E, cap, dm) -> (N, dm) gate-weighted combine."""
    N, K = d.eids.shape
    safe_e = jnp.minimum(d.eids, buf.shape[0] - 1)
    gathered = buf[safe_e.reshape(-1), d.pos.reshape(-1)].reshape(N, K, -1)
    return jnp.einsum("nkd,nk->nd", gathered.astype(jnp.float32), d.gates).astype(buf.dtype)


# ---------------------------------------------------------------------------
# Expert FFN (grouped)
# ---------------------------------------------------------------------------


def _expert_weights(params, lora: Optional[dict], lora_scale: float, name: str):
    w = params[name]
    if lora is not None and name in lora:
        a, b = lora[name]["a"], lora[name]["b"]
        delta = jnp.einsum("edr,erf->edf", a.astype(jnp.float32), b.astype(jnp.float32))
        w = w + (lora_scale * delta).astype(w.dtype)
    return w


def expert_ffn(params, buf, rt: Runtime, lora: Optional[dict] = None, lora_scale: float = 1.0):
    """buf (E, cap, d) -> (E, cap, d) via per-expert gated MLP (Eq. 2)."""
    wg = _expert_weights(params, lora, lora_scale, "wg")
    wu = _expert_weights(params, lora, lora_scale, "wu")
    wd = _expert_weights(params, lora, lora_scale, "wd")
    choice = rt.kernel_choice("moe_gmm")
    if choice.use_pallas:
        from ..kernels.moe_gmm import ops as gmm_ops

        gmm = partial(gmm_ops.gmm, backend="pallas", interpret=choice.interpret)
    else:
        gmm = lambda a, b: jnp.einsum("ecd,edf->ecf", a, b)
    h = silu(gmm(buf, wg)) * gmm(buf, wu)
    return gmm(h, wd)


# ---------------------------------------------------------------------------
# Local path
# ---------------------------------------------------------------------------


def _capacity(spec: MoESpec, n_tokens: int, zero_drop: bool) -> int:
    return n_tokens if zero_drop else min(n_tokens, spec.capacity(n_tokens))


def apply_moe_local(params, x2d, spec: MoESpec, rt: Runtime, lora=None,
                    lora_scale: float = 1.0, probs=None):
    """x2d (N, dm) -> (N, dm). Returns (y, probs)."""
    if probs is None:
        probs = router_probs(params, x2d, spec)
    gates, eids = top_k_route(probs, spec.top_k)
    cap = _capacity(spec, x2d.shape[0], rt.zero_drop)
    d = make_dispatch(gates, eids, spec, cap)
    buf = dispatch_tokens(d, x2d, spec.num_experts)
    out_buf = expert_ffn(params, buf, rt, lora, lora_scale)
    y = combine_tokens(d, out_buf)
    if spec.shared_d_ff:
        y = y + apply_mlp(params["shared"], x2d)
    return y, probs


# ---------------------------------------------------------------------------
# Sharded path (expert parallel over "model", tokens over data axes)
# ---------------------------------------------------------------------------


def apply_moe_sharded(params, x2d, spec: MoESpec, rt: Runtime, lora=None,
                      lora_scale: float = 1.0, probs=None):
    mesh = rt.mesh
    ms = rt.axis_size("model")
    E = spec.num_experts
    if ms == 1 or E % ms != 0:
        return apply_moe_local(params, x2d, spec, rt, lora, lora_scale, probs)

    N = x2d.shape[0]
    data_axes = rt.data_axes
    dp = rt.axis_size(data_axes) if data_axes else 1
    # optimized dispatch: tokens sharded over the model axis as well
    shard_model_too = _OPT_MOE_DISPATCH_SHARD and N % (dp * ms) == 0
    if shard_model_too:
        tok_axes = tuple(data_axes) + ("model",)
        tok_spec = P(tok_axes)
        n_loc = N // (dp * ms)
    else:
        token_sharded = bool(data_axes) and N % dp == 0
        tok_spec = P(data_axes) if token_sharded else P()
        n_loc = N // dp if token_sharded else N

    if probs is None:
        probs = router_probs(params, x2d, spec)
    gates, eids = top_k_route(probs, spec.top_k)
    cap = _capacity(spec, n_loc, rt.zero_drop)

    ew_spec = P("model", None, None)

    def fn(x_loc, gates_loc, eids_loc, wg, wu, wd, lora_loc):
        d = make_dispatch(gates_loc, eids_loc, spec, cap)
        buf = dispatch_tokens(d, x_loc, E)  # (E, cap, dm)
        # exchange: (E=ms*E_loc, cap, dm) -> rows of my experts from all peers
        buf = buf.reshape(ms, E // ms, cap, -1)
        buf = lax.all_to_all(buf, "model", split_axis=0, concat_axis=0, tiled=False)
        # (ms, E_loc, cap, dm): axis0 now indexes source shard
        buf = buf.transpose(1, 0, 2, 3).reshape(E // ms, ms * cap, -1)
        p_loc = {"wg": wg, "wu": wu, "wd": wd}
        out = expert_ffn(p_loc, buf, rt, lora_loc, lora_scale)
        out = out.reshape(E // ms, ms, cap, -1).transpose(1, 0, 2, 3)
        out = lax.all_to_all(out, "model", split_axis=0, concat_axis=0, tiled=False)
        out = out.reshape(E, cap, -1)
        return combine_tokens(d, out)

    lora_specs = jax.tree.map(lambda _: ew_spec, lora)
    y = shard_map(
        fn,
        mesh=mesh,
        in_specs=(tok_spec, tok_spec, tok_spec, ew_spec, ew_spec, ew_spec, lora_specs),
        out_specs=tok_spec,
        check_rep=False,
    )(x2d, gates, eids, params["wg"], params["wu"], params["wd"], lora)
    if spec.shared_d_ff:
        y = y + apply_mlp(params["shared"], x2d)
    return y, probs


def apply_moe(params, x2d, spec: MoESpec, rt: Runtime, lora=None,
              lora_scale: float = 1.0, probs=None):
    if rt.sharded and rt.model_axis is not None:
        return apply_moe_sharded(params, x2d, spec, rt, lora, lora_scale, probs)
    return apply_moe_local(params, x2d, spec, rt, lora, lora_scale, probs)
