from .model import (
    MelinoeRun,
    apply_model,
    decode_step,
    init_cache,
    init_params,
    param_shapes,
    prefill,
)
from .runtime import Runtime

__all__ = [
    "MelinoeRun",
    "apply_model",
    "decode_step",
    "init_cache",
    "init_params",
    "param_shapes",
    "prefill",
    "Runtime",
]
