"""Mamba2 (SSD, state-space duality) mixer — arXiv:2405.21060.

Chunked dual form for train/prefill; O(1)-state recurrent step for
decode. The chunked scan is also available as a Pallas kernel
(kernels/ssd_scan) — this module is the reference path and owns the
projections/conv around the scan.

Shapes: x_in (B, T, d); inner x (B, T, H, P); B/C (B, T, G, N);
state (B, H, P, N).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import SSMSpec
from .common import dense_init, rms_norm, rms_norm_init, silu


class MambaState(NamedTuple):
    conv: jax.Array  # (B, d_conv-1, conv_dim) last inputs to the causal conv
    ssm: jax.Array  # (B, H, P, N) fp32


def conv_dim(spec: SSMSpec, d_model: int) -> int:
    return spec.d_inner(d_model) + 2 * spec.n_groups * spec.d_state


def init_mamba(key, d_model: int, spec: SSMSpec, dtype):
    di = spec.d_inner(d_model)
    nh = spec.n_heads(d_model)
    cd = conv_dim(spec, d_model)
    ks = jax.random.split(key, 4)
    proj_out = 2 * di + 2 * spec.n_groups * spec.d_state + nh
    return {
        "in_proj": dense_init(ks[0], d_model, proj_out, dtype),
        "conv_w": (jax.random.normal(ks[1], (spec.d_conv, cd), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((cd,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((nh,), 0.01, jnp.float32))),  # softplus^-1
        "norm_w": rms_norm_init(di, dtype),
        "out_proj": dense_init(ks[3], di, d_model, dtype),
    }


def _split_proj(zxbcdt, spec: SSMSpec, d_model: int):
    di = spec.d_inner(d_model)
    gn = spec.n_groups * spec.d_state
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : di + di + 2 * gn]
    dt = zxbcdt[..., di + di + 2 * gn :]
    return z, xbc, dt


def _causal_conv(xbc, w, b, init: Optional[jax.Array] = None):
    """Depthwise causal conv. xbc (B, T, cd); w (dc, cd); returns (out, tail).

    ``init``: (B, dc-1, cd) carried context (decode/prefill chaining)."""
    B, T, cd = xbc.shape
    dc = w.shape[0]
    if init is None:
        init = jnp.zeros((B, dc - 1, cd), xbc.dtype)
    xp = jnp.concatenate([init, xbc], axis=1)  # (B, T+dc-1, cd)
    out = sum(xp[:, i : i + T] * w[i][None, None] for i in range(dc)) + b[None, None]
    tail = xp[:, -(dc - 1) :] if dc > 1 else jnp.zeros((B, 0, cd), xbc.dtype)
    return silu(out), tail


def _segsum(ca):
    """ca (..., cl) cumulative dA within chunk -> decay matrix (..., cl, cl):
    M[i, j] = exp(ca_i - ca_j) for i >= j else 0."""
    diff = ca[..., :, None] - ca[..., None, :]
    cl = ca.shape[-1]
    mask = jnp.tril(jnp.ones((cl, cl), bool))
    return jnp.where(mask, jnp.exp(diff), 0.0)


def ssd_chunked(x, dt, A, Bm, Cm, spec: SSMSpec, init_state=None):
    """Chunked SSD scan (pure-jnp oracle; mirrors kernels/ssd_scan).

    x (B,T,H,P); dt (B,T,H) post-softplus; A (H,) negative;
    Bm/Cm (B,T,G,N). Returns (y (B,T,H,P), final_state (B,H,P,N))."""
    Bsz, T, H, Pd = x.shape
    G, N = Bm.shape[-2:]
    hpg = H // G
    cl = min(spec.chunk, T)
    nc = -(-T // cl)
    pad = nc * cl - T
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Tp = nc * cl

    xc = x.reshape(Bsz, nc, cl, H, Pd).astype(jnp.float32)
    dtc = dt.reshape(Bsz, nc, cl, H).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, nc, cl, G, N).astype(jnp.float32)
    Cc = Cm.reshape(Bsz, nc, cl, G, N).astype(jnp.float32)

    dA = dtc * A[None, None, None, :]  # (B,nc,cl,H)
    ca = jnp.cumsum(dA, axis=2)

    # intra-chunk (dual/quadratic) term
    Lmat = _segsum(ca.transpose(0, 1, 3, 2))  # (B,nc,H,cl,cl)
    cb = jnp.einsum("bnigs,bnjgs->bngij", Cc, Bc)  # (B,nc,G,cl,cl)
    cb = jnp.repeat(cb, hpg, axis=2)  # (B,nc,H,cl,cl)
    scores = cb * Lmat * dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]
    y_diag = jnp.einsum("bnhij,bnjhp->bnihp", scores, xc)

    # per-chunk outgoing state
    decay_out = jnp.exp(ca[:, :, -1:, :] - ca)  # (B,nc,cl,H)
    Bh = jnp.repeat(Bc, hpg, axis=3)  # (B,nc,cl,H,N)
    s_loc = jnp.einsum("bnchs,bnchp->bnhps", Bh * (decay_out * dtc)[..., None], xc)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(ca[:, :, -1, :])  # (B,nc,H)
    s0 = (
        jnp.zeros((Bsz, H, Pd, N), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )

    def body(s, xs):
        dec, sl = xs  # dec (B,H), sl (B,H,P,N)
        s_new = s * dec[:, :, None, None] + sl
        return s_new, s

    scan_dec = chunk_decay.transpose(1, 0, 2)  # (nc,B,H)
    scan_sl = s_loc.transpose(1, 0, 2, 3, 4)  # (nc,B,H,P,N)
    final, s_prev = lax.scan(body, s0, (scan_dec, scan_sl))
    s_prev = s_prev.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N) state entering chunk

    # inter-chunk contribution
    Ch = jnp.repeat(Cc, hpg, axis=3)  # (B,nc,cl,H,N)
    in_decay = jnp.exp(ca)  # (B,nc,cl,H)
    y_off = jnp.einsum("bnchs,bnhps->bnchp", Ch, s_prev) * in_decay[..., None]

    y = (y_diag.transpose(0, 1, 2, 3, 4) + y_off).reshape(Bsz, Tp, H, Pd)
    return y[:, :T], final


def apply_mamba_full(params, x_in, spec: SSMSpec, *, init_state: Optional[MambaState] = None,
                     return_state: bool = False, rt=None):
    """x_in (B, T, d) -> (B, T, d).

    ``rt``: Runtime for kernel dispatch — under "pallas"/"auto" the
    chunked scan runs the Pallas SSD kernel (kernels/ssd_scan), which
    handles n_groups >= 1 and a carried initial state."""
    B, T, d_model = x_in.shape
    di = spec.d_inner(d_model)
    nh = spec.n_heads(d_model)
    gn = spec.n_groups * spec.d_state
    zxbcdt = x_in @ params["in_proj"]
    z, xbc, dt_raw = _split_proj(zxbcdt, spec, d_model)
    conv_init = init_state.conv if init_state is not None else None
    xbc, conv_tail = _causal_conv(xbc, params["conv_w"], params["conv_b"], conv_init)
    xs = xbc[..., :di].reshape(B, T, nh, spec.head_dim)
    Bm = xbc[..., di : di + gn].reshape(B, T, spec.n_groups, spec.d_state)
    Cm = xbc[..., di + gn :].reshape(B, T, spec.n_groups, spec.d_state)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"][None, None])
    A = -jnp.exp(params["A_log"])
    ssm_init = init_state.ssm if init_state is not None else None
    choice = rt.kernel_choice("ssd_scan") if rt is not None else None
    if choice is not None and choice.use_pallas:
        from ..kernels.ssd_scan import ops as ssd_ops

        y, final = ssd_ops.ssd(
            xs, dt, A, Bm, Cm, init=ssm_init, chunk=spec.chunk,
            backend="pallas", interpret=choice.interpret,
        )
        y = y.astype(jnp.float32)
    else:
        y, final = ssd_chunked(xs, dt, A, Bm, Cm, spec, ssm_init)
    y = y + params["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, T, di).astype(x_in.dtype)
    y = rms_norm(params["norm_w"], y * silu(z))
    out = y @ params["out_proj"]
    if return_state:
        return out, MambaState(conv=conv_tail, ssm=final)
    return out


def apply_mamba_decode(params, x_in, state: MambaState, spec: SSMSpec):
    """Single-token step. x_in (B, 1, d) -> (out (B,1,d), new state)."""
    B, _, d_model = x_in.shape
    di = spec.d_inner(d_model)
    nh = spec.n_heads(d_model)
    gn = spec.n_groups * spec.d_state
    hpg = nh // spec.n_groups
    zxbcdt = x_in @ params["in_proj"]
    z, xbc, dt_raw = _split_proj(zxbcdt, spec, d_model)
    # conv step using cached tail
    xp = jnp.concatenate([state.conv, xbc], axis=1)  # (B, dc, cd)
    w = params["conv_w"]
    out = jnp.einsum("btc,tc->bc", xp.astype(jnp.float32), w.astype(jnp.float32))
    xbc1 = silu(out + params["conv_b"].astype(jnp.float32))[:, None].astype(x_in.dtype)
    new_conv = xp[:, 1:]
    xs = xbc1[..., :di].reshape(B, nh, spec.head_dim).astype(jnp.float32)
    Bm = xbc1[..., di : di + gn].reshape(B, spec.n_groups, spec.d_state).astype(jnp.float32)
    Cm = xbc1[..., di + gn :].reshape(B, spec.n_groups, spec.d_state).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"][None])  # (B,H)
    A = -jnp.exp(params["A_log"])
    dec = jnp.exp(dt * A[None])  # (B,H)
    Bh = jnp.repeat(Bm, hpg, axis=1)  # (B,H,N)
    s_new = state.ssm * dec[:, :, None, None] + jnp.einsum(
        "bhn,bhp,bh->bhpn", Bh, xs, dt
    )
    Ch = jnp.repeat(Cm, hpg, axis=1)
    y = jnp.einsum("bhpn,bhn->bhp", s_new, Ch) + params["D"][None, :, None] * xs
    y = y.reshape(B, 1, di).astype(x_in.dtype)
    y = rms_norm(params["norm_w"], y * silu(z))
    return y @ params["out_proj"], MambaState(conv=new_conv, ssm=s_new)
