"""Shared layer primitives: RMSNorm, RoPE, init helpers, dtype policy."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def cdtype(cfg_dtype: str):
    return jnp.dtype(cfg_dtype)


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------


def dense_init(key, in_dim: int, out_dim: int, dtype) -> Array:
    """Truncated-normal fan-in init (stddev 1/sqrt(in_dim))."""
    std = in_dim**-0.5
    return (jax.random.truncated_normal(key, -3, 3, (in_dim, out_dim), jnp.float32) * std).astype(
        dtype
    )


def embed_init(key, vocab: int, dim: int, dtype) -> Array:
    return (jax.random.truncated_normal(key, -3, 3, (vocab, dim), jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rms_norm_init(dim: int, dtype) -> Array:
    return jnp.zeros((dim,), dtype)  # (1 + w) parameterization, gemma-style


def rms_norm(w: Array, x: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float,
               rotate_in_input_dtype: bool = False) -> Array:
    """x: (..., T, H, head_dim); positions: broadcastable to (..., T).

    Angles are always computed in f32 (bf16 cannot represent large
    positions). ``rotate_in_input_dtype`` performs the rotation itself in
    x.dtype so no f32 copy of the rotated tensor ever exists — used by
    the decode path to stop XLA promoting the KV-cache update to f32
    (EXPERIMENTS.md §Perf)."""
    head_dim = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(head_dim, theta))  # (hd/2,)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (..., T, 1, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if rotate_in_input_dtype:
        cos = cos.astype(x.dtype)
        sin = sin.astype(x.dtype)
        x1, x2 = jnp.split(x, 2, axis=-1)
        return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(x: Array, cap: Optional[float]) -> Array:
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


def silu(x: Array) -> Array:
    return x * jax.nn.sigmoid(x)
