"""Grouped-query attention with blockwise online-softmax (pure-JAX flash).

Supports: GQA, RoPE, qk-RMSNorm (qwen3/olmoe), score softcap (gemma2),
sliding-window masking, and a *banded* path that only touches the KV
chunks inside the window (so windowed layers don't pay quadratic FLOPs).

Layouts: x (B, T, d); q (B, T, Hq, hd); k/v (B, S, Hkv, hd).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import AttnSpec
from .common import apply_rope, dense_init, rms_norm, rms_norm_init, softcap

NEG = -1e30

# §Perf optimization toggles (baseline = False; flipped by the hillclimb
# harness via repro.models.attention.set_opt_flags or REPRO_OPT env)
import os as _os

_OPT_DECODE_NO_F32_CACHE = "decode_no_f32_cache" in _os.environ.get("REPRO_OPT", "")


def set_opt_flags(**kw):
    g = globals()
    for k, v in kw.items():
        key = "_OPT_" + k.upper()
        assert key in g, key
        g[key] = v


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_attn(key, d_model: int, spec: AttnSpec, dtype):
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d_model, spec.q_dim, dtype),
        "wk": dense_init(ks[1], d_model, spec.kv_dim, dtype),
        "wv": dense_init(ks[2], d_model, spec.kv_dim, dtype),
        "wo": dense_init(ks[3], spec.q_dim, d_model, dtype),
    }
    if spec.qk_norm:
        p["q_norm"] = rms_norm_init(spec.head_dim, dtype)
        p["k_norm"] = rms_norm_init(spec.head_dim, dtype)
    return p


def _project_qkv(params, spec: AttnSpec, x, positions, rope_in_dtype: bool = False):
    B, T, _ = x.shape
    q = (x @ params["wq"]).reshape(B, T, spec.n_heads, spec.head_dim)
    k = (x @ params["wk"]).reshape(B, T, spec.n_kv_heads, spec.head_dim)
    v = (x @ params["wv"]).reshape(B, T, spec.n_kv_heads, spec.head_dim)
    if spec.qk_norm:
        q = rms_norm(params["q_norm"], q)
        k = rms_norm(params["k_norm"], k)
    q = apply_rope(q, positions, spec.rope_theta, rotate_in_input_dtype=rope_in_dtype)
    k = apply_rope(k, positions, spec.rope_theta, rotate_in_input_dtype=rope_in_dtype)
    return q, k, v


# ---------------------------------------------------------------------------
# Blockwise flash attention (full sequence: train / prefill)
# ---------------------------------------------------------------------------


def _chunk_attend(q, k, v, q_pos, k_pos, spec: AttnSpec, window: Optional[int], carry):
    """One (q-chunk x kv-chunk) online-softmax update.

    q: (B, bq, Hkv, G, hd); k/v: (B, bk, Hkv, hd); carry = (m, l, acc).
    """
    m, l, acc = carry
    scale = spec.head_dim**-0.5
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    s = softcap(s, spec.attn_softcap)
    mask = k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= k_pos[None, :] > (q_pos[:, None] - window)
    s = jnp.where(mask[None, None, None], s, NEG)
    m_new = jnp.maximum(m, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + p.sum(axis=-1)
    acc_new = acc * corr[..., None] + jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    return (m_new, l_new, acc_new)


def _flash_q_chunk(q, k, v, q_pos, k_pos, spec: AttnSpec, window, bk: int):
    """Attend one q chunk against all of k/v, scanning kv chunks."""
    B, bq, Hkv, G, hd = q.shape
    S = k.shape[1]
    nk = -(-S // bk)
    pad = nk * bk - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        # padded slots get a huge *positive* position so the causal test fails
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=2 * 10**9)
    k = k.reshape(B, nk, bk, Hkv, hd).transpose(1, 0, 2, 3, 4)
    v = v.reshape(B, nk, bk, Hkv, hd).transpose(1, 0, 2, 3, 4)
    k_pos = k_pos.reshape(nk, bk)
    m0 = jnp.full((B, Hkv, G, bq), NEG, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, bq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, bq, hd), jnp.float32)

    def body(carry, xs):
        kj, vj, kpj = xs
        return _chunk_attend(q, kj, vj, q_pos, kpj, spec, window, carry), None

    (m, l, acc), _ = lax.scan(body, (m0, l0, a0), (k, v, k_pos))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4)  # (B, bq, Hkv, G, hd)


def flash_attention(
    q, k, v, spec: AttnSpec, *, q_offset: int | jax.Array = 0, window: Optional[int] = None,
    bq: int = 512, bk: int = 1024,
):
    """Causal blockwise attention. q (B,T,Hq,hd), k/v (B,S,Hkv,hd).

    ``q_offset``: position of q[0] relative to k[0] (prefix decode).
    Windowed layers take the *banded* path: each q chunk only sees the
    ``window+bq`` KV slice that can pass the mask.
    """
    B, T, Hq, hd = q.shape
    S = k.shape[1]
    G = Hq // spec.n_kv_heads
    q = q.reshape(B, T, spec.n_kv_heads, G, hd)
    bq = min(bq, T)
    nq = -(-T // bq)
    padq = nq * bq - T
    q_pos_full = q_offset + jnp.arange(T)
    if padq:
        q = jnp.pad(q, ((0, 0), (0, padq), (0, 0), (0, 0), (0, 0)))
        q_pos_full = jnp.pad(q_pos_full, (0, padq), constant_values=2 * (10**9))
    qs = q.reshape(B, nq, bq, spec.n_kv_heads, G, hd).transpose(1, 0, 2, 3, 4, 5)
    q_pos = q_pos_full.reshape(nq, bq)

    banded = window is not None and S > (window + bq)
    if banded:
        wb = window + bq
        kp = jnp.pad(k, ((0, 0), (wb, 0), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (wb, 0), (0, 0), (0, 0)))
        kpos_pad = jnp.concatenate([jnp.full((wb,), 2 * 10**9), jnp.arange(S)])

        def body(_, xs):
            qi, qpi, idx = xs
            # highest kv position this chunk can see is its last q position
            end = jnp.clip((idx + 1) * bq - q_offset, 0, S) + wb  # exclusive, in padded coords
            start = end - wb
            kj = lax.dynamic_slice_in_dim(kp, start, wb, axis=1)
            vj = lax.dynamic_slice_in_dim(vp, start, wb, axis=1)
            kpj = lax.dynamic_slice_in_dim(kpos_pad, start, wb, axis=0)
            o = _flash_q_chunk(qi, kj, vj, qpi, kpj, spec, window, bk)
            return None, o

        _, outs = lax.scan(body, None, (qs, q_pos, jnp.arange(nq)))
    else:
        k_pos = jnp.arange(S)

        def body(_, xs):
            qi, qpi = xs
            o = _flash_q_chunk(qi, k, v, qpi, k_pos, spec, window, bk)
            return None, o

        _, outs = lax.scan(body, None, (qs, q_pos))

    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * bq, Hq, hd)
    return out[:, :T].astype(k.dtype)


# ---------------------------------------------------------------------------
# Single-token decode against a (ring-buffer) KV cache
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    k: jax.Array  # (B, W, Hkv, hd)
    v: jax.Array  # (B, W, Hkv, hd)
    slot_pos: jax.Array  # (B, W) int32 per-row; -1 = empty


def init_kv_cache(batch: int, n_slots: int, spec: AttnSpec, dtype) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, n_slots, spec.n_kv_heads, spec.head_dim), dtype),
        v=jnp.zeros((batch, n_slots, spec.n_kv_heads, spec.head_dim), dtype),
        slot_pos=jnp.full((batch, n_slots), -1, jnp.int32),
    )


def cache_from_prefill(k, v, spec: AttnSpec, n_slots: int) -> KVCache:
    """Build a (possibly ring) cache from prefill K/V of length T."""
    B, T, H, hd = k.shape
    if T <= n_slots:
        cache = init_kv_cache(B, n_slots, spec, k.dtype)
        return KVCache(
            k=cache.k.at[:, :T].set(k),
            v=cache.v.at[:, :T].set(v),
            slot_pos=cache.slot_pos.at[:, :T].set(jnp.arange(T)),
        )
    pos = jnp.arange(T - n_slots, T)
    slots = pos % n_slots
    return KVCache(
        k=jnp.zeros((B, n_slots, H, hd), k.dtype).at[:, slots].set(k[:, -n_slots:]),
        v=jnp.zeros((B, n_slots, H, hd), k.dtype).at[:, slots].set(v[:, -n_slots:]),
        slot_pos=jnp.broadcast_to(
            jnp.full((n_slots,), -1, jnp.int32).at[slots].set(pos), (B, n_slots)
        ),
    )


def decode_attend(params, spec: AttnSpec, x, cache: KVCache, pos, window: Optional[int]):
    """x: (B, 1, d); pos: int32 position of the new token — a scalar
    (whole batch in lockstep) or a (B,) vector (continuous batching:
    every row decodes at its own position).

    Returns (out (B,1,d), updated cache)."""
    B = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    lockstep = pos.ndim == 0
    if lockstep:
        pos = jnp.broadcast_to(pos, (B,))
    positions = pos[:, None]  # (B, 1)
    # rope rotation in the cache dtype under the opt flag: with an f32
    # rotated value in scope, XLA promotes the whole stacked KV cache to
    # f32 inside the layer loop (§Perf deepseek decode hillclimb)
    q, k_new, v_new = _project_qkv(params, spec, x, positions,
                                   rope_in_dtype=_OPT_DECODE_NO_F32_CACHE)
    W = cache.k.shape[1]
    slot = pos % W  # (B,)
    if lockstep:  # hot path: one dynamic-update-slice, no scatter
        k_c = lax.dynamic_update_slice_in_dim(cache.k, k_new, slot[0], axis=1)
        v_c = lax.dynamic_update_slice_in_dim(cache.v, v_new, slot[0], axis=1)
        slot_pos = lax.dynamic_update_slice_in_dim(
            cache.slot_pos, positions, slot[0], axis=1
        )
    else:  # continuous batching: every row writes its own ring slot
        rows = jnp.arange(B)
        k_c = cache.k.at[rows, slot].set(k_new[:, 0])
        v_c = cache.v.at[rows, slot].set(v_new[:, 0])
        slot_pos = cache.slot_pos.at[rows, slot].set(pos)

    G = spec.n_heads // spec.n_kv_heads
    qg = q.reshape(B, 1, spec.n_kv_heads, G, spec.head_dim)
    scale = spec.head_dim**-0.5
    if _OPT_DECODE_NO_F32_CACHE:
        # §Perf decode hillclimb: preferred_element_type accumulates in fp32
        # WITHOUT materializing an fp32 copy of the whole cache
        s = jnp.einsum(
            "bqhgd,bkhd->bhgqk", qg, k_c, preferred_element_type=jnp.float32
        ) * scale
    else:  # paper-faithful baseline path (fp32 upcast of K before the dot)
        s = jnp.einsum(
            "bqhgd,bkhd->bhgqk", qg.astype(jnp.float32), k_c.astype(jnp.float32)
        ) * scale
    s = softcap(s, spec.attn_softcap)
    valid = (slot_pos >= 0) & (slot_pos <= pos[:, None])  # (B, W)
    if window is not None:
        valid &= slot_pos > (pos[:, None] - window)
    s = jnp.where(valid[:, None, None, None, :], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    if _OPT_DECODE_NO_F32_CACHE:
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v_c.dtype), v_c,
                       preferred_element_type=jnp.float32)
    else:
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v_c.astype(jnp.float32))
    o = o.reshape(B, 1, spec.q_dim).astype(x.dtype)
    out = o @ params["wo"]
    return out, KVCache(k_c, v_c, slot_pos)


# ---------------------------------------------------------------------------
# Full attention layer (train / prefill)
# ---------------------------------------------------------------------------


def attend_full(params, spec: AttnSpec, x, positions, window: Optional[int],
                return_kv=False, rt=None):
    """x (B,T,d) -> (B,T,d). positions (B,T) absolute.

    ``rt``: Runtime for kernel dispatch — under the "pallas"/"auto"
    backends the prefill attention runs the fused Pallas kernel
    (kernels/flash_attn) instead of the pure-JAX blockwise path, when
    the shapes fit its VMEM-resident-KV envelope."""
    q, k, v = _project_qkv(params, spec, x, positions)
    o = None
    if rt is not None:
        choice = rt.kernel_choice("flash_attn")
        if choice.use_pallas:
            from ..kernels.flash_attn import ops as flash_ops

            if flash_ops.supported(q.shape, k.shape, choice.interpret):
                B, T, Hq, hd = q.shape
                G = Hq // spec.n_kv_heads
                qg = q.reshape(B, T, spec.n_kv_heads, G, hd)
                o = flash_ops.flash(
                    qg, k, v, softcap=spec.attn_softcap, window=window,
                    backend="pallas", interpret=choice.interpret,
                ).reshape(B, T, Hq, hd)
    if o is None:
        o = flash_attention(q, k, v, spec, window=window)
    out = o.reshape(*x.shape[:2], spec.q_dim) @ params["wo"]
    if return_kv:
        return out, (k, v)
    return out
