"""Model assembly: layout groups scanned with ``lax.scan``, shared blocks,
embeddings, MELINOE loss accumulation, prefill and decode paths.

Parameter tree:
  params = {
    "embed": (V, d),
    "lm_head": (d, V)           # absent when tie_embeddings
    "final_norm": (d,),
    "shared": {block params}    # zamba2 shared-attention weights
    "groups": { "g0": {"p0": stacked block params (R, ...), "p1": ...},
                "g1": ... },
  }
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import MelinoeSpec, ModelConfig
from .blocks import apply_block_decode, apply_block_full, init_block, init_block_cache
from .common import embed_init, rms_norm, rms_norm_init, softcap
from .runtime import Runtime


@dataclass(frozen=True)
class MelinoeRun:
    """Melinoe auxiliary-loss request threaded through the forward pass."""

    spec: MelinoeSpec
    cache_capacity: int
    # stacked base-router weights per group/position (same_trajectory mode);
    # None disables the rank-matching term.
    base_routers: Optional[Dict[str, Dict[str, jax.Array]]] = None


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_params(key, cfg: ModelConfig, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, len(cfg.layout) + 3)
    params: dict = {
        "embed": embed_init(keys[0], cfg.vocab, cfg.d_model, dtype),
        "final_norm": rms_norm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        from .common import dense_init

        params["lm_head"] = dense_init(keys[1], cfg.d_model, cfg.vocab, dtype)
    # shared block (zamba2): initialized once
    shared_kinds = {n for n, b in cfg.block_defs.items() if b.kind == "shared_attn"}
    if shared_kinds:
        (sname,) = shared_kinds
        params["shared"] = init_block(keys[2], cfg, cfg.block_defs[sname], dtype)

    groups = {}
    for gi, g in enumerate(cfg.layout):
        gkey = keys[3 + gi]
        gparams = {}
        for pi, bname in enumerate(g.pattern):
            b = cfg.block_defs[bname]
            if b.kind == "shared_attn":
                continue  # weights live in params["shared"]
            pkeys = jax.random.split(jax.random.fold_in(gkey, pi), g.repeats)
            gparams[f"p{pi}"] = jax.vmap(lambda k: init_block(k, cfg, b, dtype))(pkeys)
        groups[f"g{gi}"] = gparams
    params["groups"] = groups
    return params


def param_shapes(cfg: ModelConfig):
    return jax.eval_shape(lambda k: init_params(k, cfg), jax.random.key(0))


# ---------------------------------------------------------------------------
# Embedding / logits
# ---------------------------------------------------------------------------


def embed_tokens(params, cfg: ModelConfig, tokens, prefix_embed=None):
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    if prefix_embed is not None:
        x = jnp.concatenate([prefix_embed.astype(x.dtype), x], axis=1)
    return x


import os as _os

# §Perf: shard the LM-head/loss computation's token dim over ALL mesh axes
# (baseline shards tokens over data only, so every model-shard computes the
# full-vocab logits for its whole local batch)
_OPT_LOSS_TOKEN_SHARD = "loss_token_shard" in _os.environ.get("REPRO_OPT", "")


def set_opt_flags(**kw):
    g = globals()
    for k, v in kw.items():
        key = "_OPT_" + k.upper()
        assert key in g, key
        g[key] = v


def compute_logits(params, cfg: ModelConfig, x, rt: Runtime):
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    if _OPT_LOSS_TOKEN_SHARD and rt.sharded and x.shape[1] > 1:
        axes = tuple(rt.data_axes) + (("model",) if rt.model_axis else ())
        # fold tokens into the batch-of-tokens dim and shard it over all axes
        B, T, d = x.shape
        x2 = rt.constrain(x.reshape(B * T, d), axes)
        logits = x2 @ head
        logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
        logits = rt.constrain(logits, axes, None)
        return logits.reshape(B, T, -1)
    logits = x @ head
    logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    return rt.constrain(logits, rt.batch_spec_entry(), None, rt.model_axis)


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------


def _melinoe_layer(carry_losses, aux, base_router, mel: MelinoeRun, top_k: int):
    from ..core.losses import melinoe_layer_losses

    cs_sum, rm_sum = carry_losses
    cs, rm = melinoe_layer_losses(
        probs=aux["probs"],
        moe_h=aux.get("moe_h"),
        base_router=base_router,
        spec=mel.spec,
        cache_capacity=mel.cache_capacity,
        top_k=top_k,
    )
    return (cs_sum + cs, rm_sum + rm)


def apply_model(
    params,
    cfg: ModelConfig,
    tokens,
    rt: Runtime,
    *,
    prefix_embed=None,
    melinoe: Optional[MelinoeRun] = None,
    collect_probs: bool = False,
    want_cache: bool = False,
    cache_slots: int = 0,
    window_override: Optional[int] = None,
    lora=None,
    lora_scale: float = 1.0,
    remat: bool = False,
):
    """Returns (logits, aux) where aux = {"cs_loss", "rm_loss", "probs", "cache"}.

    ``probs`` (collect_probs): list of (R, B, T, E) stacked router
    distributions per (group, position). ``cache``: per-group stacked
    block caches (prefill).
    """
    x = embed_tokens(params, cfg, tokens, prefix_embed)
    x = rt.constrain(x, rt.batch_spec_entry())
    B, T, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))

    want_probs = collect_probs or melinoe is not None
    cs0 = jnp.zeros((), jnp.float32)
    losses = (cs0, cs0)
    probs_out = []
    cache_out = {}

    for gi, g in enumerate(cfg.layout):
        gname = f"g{gi}"
        gparams = params["groups"][gname]
        base_g = None
        if melinoe is not None and melinoe.base_routers is not None:
            base_g = melinoe.base_routers.get(gname)
        lora_g = lora.get(gname) if lora is not None else None

        def body(carry, xs):
            x, losses = carry
            gp, base_p, lora_p = xs
            ys = {}
            for pi, bname in enumerate(g.pattern):
                b = cfg.block_defs[bname]
                bparams = params["shared"] if b.kind == "shared_attn" else gp[f"p{pi}"]
                blora = lora_p.get(f"p{pi}") if lora_p is not None else None
                x, aux = apply_block_full(
                    bparams, cfg, b, x, positions, rt,
                    window_override=window_override,
                    want_cache=want_cache, cache_slots=cache_slots,
                    want_probs=want_probs and b.moe is not None,
                    lora=blora, lora_scale=lora_scale,
                )
                if b.moe is not None and melinoe is not None:
                    br = base_p.get(f"p{pi}") if base_p is not None else None
                    losses = _melinoe_layer(losses, aux, br, melinoe, b.moe.top_k)
                ys_aux = {}
                if collect_probs and "probs" in aux:
                    ys_aux["probs"] = aux["probs"]
                if want_cache and "kv" in aux:
                    ys_aux["kv"] = aux["kv"]
                ys[f"p{pi}"] = ys_aux
            return (x, losses), ys

        if remat:
            body = jax.checkpoint(body)  # per-layer remat: O(L) activation memory
        (x, losses), ys = lax.scan(body, (x, losses), (gparams, base_g, lora_g))
        if collect_probs:
            for pi, bname in enumerate(g.pattern):
                if cfg.block_defs[bname].moe is not None:
                    probs_out.append(ys[f"p{pi}"]["probs"])
        if want_cache:
            cache_out[gname] = {
                f"p{pi}": ys[f"p{pi}"]["kv"] for pi in range(len(g.pattern))
            }

    logits = compute_logits(params, cfg, x, rt)
    n_moe = max(cfg.n_moe_layers, 1)
    aux = {
        "cs_loss": losses[0] / n_moe,
        "rm_loss": losses[1] / n_moe,
    }
    if collect_probs:
        aux["probs"] = probs_out
    if want_cache:
        cache_out["pos"] = jnp.asarray(T, jnp.int32)
        aux["cache"] = cache_out
    return logits, aux


# ---------------------------------------------------------------------------
# KV/SSM cache init + single-token decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, n_slots: int, dtype=None,
               window_override: Optional[int] = None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    cache: dict = {"pos": jnp.zeros((), jnp.int32)}
    for gi, g in enumerate(cfg.layout):
        gcache = {}
        for pi, bname in enumerate(g.pattern):
            b = cfg.block_defs[bname]
            one = init_block_cache(cfg, b, batch, n_slots, window_override, dtype)
            gcache[f"p{pi}"] = jax.tree.map(
                lambda a: jnp.tile(a[None], (g.repeats,) + (1,) * a.ndim), one
            )
        cache[f"g{gi}"] = gcache
    return cache


def decode_step(
    params,
    cfg: ModelConfig,
    tokens,  # (B, 1)
    cache,
    rt: Runtime,
    *,
    window_override: Optional[int] = None,
    collect_probs: bool = False,
    lora=None,
    lora_scale: float = 1.0,
):
    """One autoregressive step. Returns (logits (B,1,V), new cache, aux)."""
    pos = cache["pos"]
    x = embed_tokens(params, cfg, tokens)
    x = rt.constrain(x, rt.batch_spec_entry())
    probs_out = []
    new_cache = {"pos": pos + 1}

    for gi, g in enumerate(cfg.layout):
        gname = f"g{gi}"
        gparams = params["groups"][gname]
        gcache = cache[gname]
        lora_g = lora.get(gname) if lora is not None else None

        def body(carry, xs):
            x = carry
            gp, gc, lora_p = xs
            new_gc = {}
            ys_aux = {}
            for pi, bname in enumerate(g.pattern):
                b = cfg.block_defs[bname]
                bparams = params["shared"] if b.kind == "shared_attn" else gp[f"p{pi}"]
                blora = lora_p.get(f"p{pi}") if lora_p is not None else None
                x, new_c, aux = apply_block_decode(
                    bparams, cfg, b, x, gc[f"p{pi}"], pos, rt,
                    window_override=window_override,
                    want_probs=collect_probs and b.moe is not None,
                    lora=blora, lora_scale=lora_scale,
                )
                new_gc[f"p{pi}"] = new_c
                if collect_probs and "probs" in aux:
                    ys_aux[f"probs{pi}"] = aux["probs"]
            return x, {"cache": new_gc, "aux": ys_aux}

        x, ys = lax.scan(body, x, (gparams, gcache, lora_g))
        new_cache[gname] = ys["cache"]
        for pi, bname in enumerate(g.pattern):
            if collect_probs and cfg.block_defs[bname].moe is not None:
                probs_out.append(ys["aux"][f"probs{pi}"])

    logits = compute_logits(params, cfg, x, rt)
    aux = {"probs": probs_out} if collect_probs else {}
    return logits, new_cache, aux


def prefill(
    params,
    cfg: ModelConfig,
    tokens,
    rt: Runtime,
    *,
    prefix_embed=None,
    n_slots: Optional[int] = None,
    window_override: Optional[int] = None,
    lora=None,
    lora_scale: float = 1.0,
):
    """Process the prompt, returning (last-position logits, cache)."""
    T = tokens.shape[1] + (prefix_embed.shape[1] if prefix_embed is not None else 0)
    slots = n_slots or T
    logits, aux = apply_model(
        params, cfg, tokens, rt,
        prefix_embed=prefix_embed,
        want_cache=True, cache_slots=slots,
        window_override=window_override,
        lora=lora, lora_scale=lora_scale,
    )
    return logits[:, -1:], aux["cache"]
