"""Gated (SwiGLU) dense MLP."""
from __future__ import annotations

import jax

from .common import dense_init, silu


def init_mlp(key, d_model: int, d_ff: int, dtype):
    ks = jax.random.split(key, 3)
    return {
        "wg": dense_init(ks[0], d_model, d_ff, dtype),
        "wu": dense_init(ks[1], d_model, d_ff, dtype),
        "wd": dense_init(ks[2], d_ff, d_model, dtype),
    }


def apply_mlp(params, x):
    h = silu(x @ params["wg"]) * (x @ params["wu"])
    return h @ params["wd"]
