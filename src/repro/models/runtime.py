"""Runtime context threaded through model code.

Keeps layer code mesh-agnostic: with ``mesh=None`` everything is plain
local JAX (smoke tests, the offload engine); with a mesh, the MoE layer
switches to shard_map expert parallelism and activations get sharding
constraints.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class Runtime:
    mesh: Optional[Mesh] = None
    kernel_backend: str = "ref"  # dispatch spec: "ref" | "pallas" | "auto",
    # optionally per-op ("auto,flash_attn=ref"); see kernels/dispatch.py.
    # REPRO_KERNEL_BACKEND in the environment overrides this field.
    use_kernels: Optional[bool] = None  # legacy alias: True -> "auto"
    zero_drop: bool = False  # MoE capacity large enough for zero token drops
    interpret: Optional[bool] = None  # Pallas interpret mode; None = platform
    # autodetect (interpret off-TPU, compiled on TPU)
    profile: str = "tp"  # "tp" (TP/FSDP hybrid) | "pure_fsdp" (§Perf: no TP
    # activation all-reduces; batch + weights sharded over ALL mesh axes)

    def __post_init__(self):
        if self.use_kernels and self.kernel_backend == "ref":
            object.__setattr__(self, "kernel_backend", "auto")

    def kernel_choice(self, op: str):
        """Resolve the backend for one kernel family (kernels/dispatch.py).

        The sharded model path keeps the reference implementations — the
        Pallas kernels are single-device bodies not validated under
        shard_map yet — and that guard must hold even against the
        REPRO_KERNEL_BACKEND env override, so it bypasses dispatch."""
        from ..kernels import dispatch

        if self.sharded:
            return dispatch.KernelChoice("ref", False)
        return dispatch.resolve(op, self.kernel_backend, interpret=self.interpret)

    @property
    def sharded(self) -> bool:
        return self.mesh is not None and self.mesh.devices.size > 1

    @property
    def data_axes(self) -> Tuple[str, ...]:
        if self.mesh is None:
            return ()
        if self.profile == "pure_fsdp":
            return tuple(a for a in ("pod", "data", "model") if a in self.mesh.axis_names)
        return tuple(a for a in ("pod", "data") if a in self.mesh.axis_names)

    @property
    def model_axis(self) -> Optional[str]:
        if self.mesh is None or "model" not in self.mesh.axis_names:
            return None
        if self.profile == "pure_fsdp":
            return None  # no tensor parallelism; experts stay data-local
        return "model"

    def axis_size(self, names) -> int:
        if self.mesh is None:
            return 1
        if isinstance(names, str):
            names = (names,)
        n = 1
        for a in names:
            n *= self.mesh.shape[a]
        return n

    # -- sharding helpers ------------------------------------------------
    def prune_spec(self, shape, spec: P) -> P:
        """Drop mesh axes that do not evenly divide the corresponding dim."""
        if self.mesh is None:
            return P()
        out = []
        for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
            if entry is None:
                out.append(None)
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            kept = []
            prod = 1
            for a in axes:
                if dim % (prod * self.mesh.shape[a]) == 0:
                    kept.append(a)
                    prod *= self.mesh.shape[a]
            out.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
        return P(*out)

    def constrain(self, x, *spec_entries):
        """with_sharding_constraint with divisibility pruning; no-op unsharded."""
        if not self.sharded:
            return x
        spec = self.prune_spec(x.shape, P(*spec_entries))
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    def batch_spec_entry(self):
        return self.data_axes if self.data_axes else None
