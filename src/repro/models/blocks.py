"""Block zoo: init/apply for each block kind, full-sequence and decode."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..configs.base import BlockSpec, ModelConfig
from . import attention as attn_mod
from . import mamba2 as mamba_mod
from .attention import KVCache, attend_full, cache_from_prefill, decode_attend, init_attn
from .common import rms_norm, rms_norm_init
from .mamba2 import MambaState, apply_mamba_decode, apply_mamba_full, init_mamba
from .mlp import apply_mlp, init_mlp
from .moe import apply_moe, init_moe, router_probs
from .runtime import Runtime


def init_block(key, cfg: ModelConfig, b: BlockSpec, dtype):
    """``shared_attn`` blocks are NOT initialized here (they live in the
    model's shared subtree and are referenced by every occurrence)."""
    ks = jax.random.split(key, 2)
    p: dict = {"ln1": rms_norm_init(cfg.d_model, dtype)}
    if b.kind == "mamba":
        p["mixer"] = init_mamba(ks[0], cfg.d_model, b.ssm, dtype)
        return p
    p["mixer"] = init_attn(ks[0], cfg.d_model, b.attn, dtype)
    p["ln2"] = rms_norm_init(cfg.d_model, dtype)
    if b.kind == "attn_moe":
        p["ffn"] = init_moe(ks[1], cfg.d_model, b.moe, dtype)
    else:
        p["ffn"] = init_mlp(ks[1], cfg.d_model, b.d_ff, dtype)
    return p


def effective_window(b: BlockSpec, window_override: Optional[int]) -> Optional[int]:
    if b.attn is None:
        return None
    w = b.attn.window
    if window_override is not None:
        w = min(w, window_override) if w is not None else window_override
    return w


class BlockAux(NamedTuple):
    probs: Optional[jax.Array] = None  # router distribution (B, T, E)
    moe_h: Optional[jax.Array] = None  # hidden states fed to the router
    kv: Optional[Any] = None  # KVCache / MambaState for prefill


def apply_block_full(
    params,
    cfg: ModelConfig,
    b: BlockSpec,
    x,
    positions,
    rt: Runtime,
    *,
    window_override: Optional[int] = None,
    want_cache: bool = False,
    cache_slots: int = 0,
    want_probs: bool = False,
    lora=None,
    lora_scale: float = 1.0,
) -> tuple:
    """Full-sequence (train / prefill) application. x (B, T, d)."""
    aux = {}
    h = rms_norm(params["ln1"], x, cfg.norm_eps)
    if b.kind == "mamba":
        if want_cache:
            y, state = apply_mamba_full(params["mixer"], h, b.ssm,
                                        return_state=True, rt=rt)
            aux["kv"] = state
        else:
            y = apply_mamba_full(params["mixer"], h, b.ssm, rt=rt)
        x = x + y
        return x, aux

    w = effective_window(b, window_override)
    if want_cache:
        y, (k, v) = attend_full(params["mixer"], b.attn, h, positions, w,
                                return_kv=True, rt=rt)
        aux["kv"] = cache_from_prefill(k, v, b.attn, cache_slots or k.shape[1])
    else:
        y = attend_full(params["mixer"], b.attn, h, positions, w, rt=rt)
    x = x + y
    x = rt.constrain(x, rt.batch_spec_entry())

    h2 = rms_norm(params["ln2"], x, cfg.norm_eps)
    if b.kind == "attn_moe":
        B, T, dm = h2.shape
        h2f = h2.reshape(B * T, dm)
        probs = router_probs(params["ffn"], h2f, b.moe)
        y2, _ = apply_moe(params["ffn"], h2f, b.moe, rt, lora=lora,
                          lora_scale=lora_scale, probs=probs)
        y2 = y2.reshape(B, T, dm)
        if want_probs:
            aux["probs"] = probs.reshape(B, T, -1)
            aux["moe_h"] = h2
    else:
        y2 = apply_mlp(params["ffn"], h2)
    x = x + y2
    return rt.constrain(x, rt.batch_spec_entry()), aux


def apply_block_decode(
    params,
    cfg: ModelConfig,
    b: BlockSpec,
    x,
    cache,
    pos,
    rt: Runtime,
    *,
    window_override: Optional[int] = None,
    want_probs: bool = False,
    lora=None,
    lora_scale: float = 1.0,
) -> tuple:
    """Single-token step. x (B, 1, d); cache is this block's state."""
    aux = {}
    h = rms_norm(params["ln1"], x, cfg.norm_eps)
    if b.kind == "mamba":
        y, new_state = apply_mamba_decode(params["mixer"], h, cache, b.ssm)
        return x + y, new_state, aux

    w = effective_window(b, window_override)
    y, new_cache = decode_attend(params["mixer"], b.attn, h, cache, pos, w)
    x = x + y
    h2 = rms_norm(params["ln2"], x, cfg.norm_eps)
    if b.kind == "attn_moe":
        B, T, dm = h2.shape
        h2f = h2.reshape(B * T, dm)
        probs = router_probs(params["ffn"], h2f, b.moe)
        rt_d = rt if rt.zero_drop else dataclasses.replace(rt, zero_drop=True)
        y2, _ = apply_moe(params["ffn"], h2f, b.moe, rt_d, lora=lora,
                          lora_scale=lora_scale, probs=probs)
        y2 = y2.reshape(B, T, dm)
        if want_probs:
            aux["probs"] = probs.reshape(B, T, -1)
    else:
        y2 = apply_mlp(params["ffn"], h2)
    return x + y2, new_cache, aux


def init_block_cache(cfg: ModelConfig, b: BlockSpec, batch: int, n_slots: int,
                     window_override: Optional[int], dtype):
    if b.kind == "mamba":
        s = b.ssm
        return MambaState(
            conv=jnp.zeros((batch, s.d_conv - 1, mamba_mod.conv_dim(s, cfg.d_model)), dtype),
            ssm=jnp.zeros((batch, s.n_heads(cfg.d_model), s.head_dim, s.d_state), jnp.float32),
        )
    w = effective_window(b, window_override)
    slots = min(n_slots, w) if w is not None else n_slots
    return attn_mod.init_kv_cache(batch, slots, b.attn, dtype)
