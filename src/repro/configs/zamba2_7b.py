"""zamba2-7b [hybrid]: Mamba2 backbone + shared attention blocks
[arXiv:2411.15242]. 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64.

Layout: 13 x (5 mamba + 1 shared-attention) + 3 mamba = 81 layers.
The shared-attention block's parameters are *shared* across all 13
occurrences (zamba's defining trait) — they live in the model's
``shared`` subtree, not in the scanned stack.
"""
from .base import AttnSpec, BlockSpec, LayoutGroup, ModelConfig, SSMSpec
from .registry import register


@register("zamba2-7b")
def config() -> ModelConfig:
    attn = AttnSpec(n_heads=32, n_kv_heads=32, head_dim=112)
    ssm = SSMSpec(d_state=64, d_conv=4, expand=2, head_dim=64)
    return ModelConfig(
        name="zamba2-7b",
        family="hybrid",
        d_model=3584,
        vocab=32_000,
        block_defs={
            "mamba": BlockSpec(kind="mamba", ssm=ssm),
            "shared_attn": BlockSpec(kind="shared_attn", attn=attn, d_ff=14_336),
        },
        layout=(
            LayoutGroup(("mamba",) * 5 + ("shared_attn",), 13),
            LayoutGroup(("mamba",), 3),
        ),
        source="arXiv:2411.15242",
    )
