"""deepseek-moe-16b [moe]: 2 shared + 64 routed top-6, fine-grained
[arXiv:2401.06066]. 28L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=102400, MoE 64e top-6. First layer is a dense MLP (d_ff=10944),
remaining 27 are MoE — matching the release.

MELINOE applies directly; the 2 shared experts are always GPU/HBM
resident (never offloaded, excluded from the cache budget C).
"""
from .base import AttnSpec, BlockSpec, LayoutGroup, MelinoeSpec, ModelConfig, MoESpec
from .registry import register


@register("deepseek-moe-16b")
def config() -> ModelConfig:
    attn = AttnSpec(n_heads=16, n_kv_heads=16, head_dim=128)
    moe = MoESpec(num_experts=64, top_k=6, d_ff=1408, num_shared=2, shared_d_ff=2 * 1408)
    return ModelConfig(
        name="deepseek-moe-16b",
        family="moe",
        d_model=2048,
        vocab=102_400,
        block_defs={
            "dense0": BlockSpec(kind="attn_dense", attn=attn, d_ff=10_944),
            "moe": BlockSpec(kind="attn_moe", attn=attn, moe=moe),
        },
        layout=(LayoutGroup(("dense0",), 1), LayoutGroup(("moe",), 27)),
        melinoe=MelinoeSpec(),
        source="arXiv:2401.06066",
    )
