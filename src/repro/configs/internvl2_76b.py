"""internvl2-76b [vlm]: InternViT + LLM backbone [arXiv:2404.16821].
80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.

The vision encoder + projector are stubs: ``input_specs`` provides a
precomputed ``prefix_embed`` (B, 256, d_model) of projected patch
embeddings; this config is the language decoder that consumes them.
"""
from .base import AttnSpec, BlockSpec, LayoutGroup, ModelConfig
from .registry import register


@register("internvl2-76b")
def config() -> ModelConfig:
    attn = AttnSpec(n_heads=64, n_kv_heads=8, head_dim=128)
    return ModelConfig(
        name="internvl2-76b",
        family="vlm",
        d_model=8192,
        vocab=128_256,
        block_defs={"dense": BlockSpec(kind="attn_dense", attn=attn, d_ff=28_672)},
        layout=(LayoutGroup(("dense",), 80),),
        prefix_len=256,
        source="arXiv:2404.16821",
    )
