"""Architecture registry: ``--arch <id>`` resolves through here."""
from __future__ import annotations

import importlib
from typing import Callable, Dict

from .base import ModelConfig, make_smoke

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}

# module name per arch id (one file per assigned architecture + paper's own)
_MODULES = {
    "musicgen-medium": "repro.configs.musicgen_medium",
    "gemma2-27b": "repro.configs.gemma2_27b",
    "granite-moe-1b-a400m": "repro.configs.granite_moe_1b_a400m",
    "stablelm-12b": "repro.configs.stablelm_12b",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "command-r-plus-104b": "repro.configs.command_r_plus_104b",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "internvl2-76b": "repro.configs.internvl2_76b",
    "qwen3-4b": "repro.configs.qwen3_4b",
    "mamba2-130m": "repro.configs.mamba2_130m",
    # paper's own backbones (reproduction targets)
    "olmoe": "repro.configs.olmoe",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "phi35-moe": "repro.configs.phi35_moe",
    # reduced reproduction workhorse
    "olmoe-mini": "repro.configs.olmoe_mini",
}

ASSIGNED = tuple(list(_MODULES)[:10])
PAPER = ("olmoe", "mixtral-8x7b", "phi35-moe")


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn

    return deco


def get_config(name: str) -> ModelConfig:
    smoke = name.endswith("-smoke")
    base = name[: -len("-smoke")] if smoke else name
    if base not in _REGISTRY:
        if base not in _MODULES:
            raise KeyError(f"unknown arch {base!r}; known: {sorted(_MODULES)}")
        importlib.import_module(_MODULES[base])
    cfg = _REGISTRY[base]()
    cfg.validate()
    if smoke:
        cfg = make_smoke(cfg)
        cfg.validate()
    return cfg


def list_archs():
    return sorted(_MODULES)
