"""Configuration dataclasses for the repro framework.

A model is described as a *layout* of block groups. Each group is a
repeated pattern of named blocks; the pattern is scanned with
``lax.scan`` over the repeat dimension so heterogeneous stacks (gemma2
local/global alternation, zamba2 mamba+shared-attention interleave,
deepseek dense-first-layer) still compile to compact HLO.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field, replace
from typing import Mapping, Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-specs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AttnSpec:
    """Grouped-query attention spec."""

    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    attn_softcap: Optional[float] = None
    window: Optional[int] = None  # None => global causal attention

    def __post_init__(self):
        assert self.n_heads % self.n_kv_heads == 0, (
            f"n_heads={self.n_heads} not divisible by n_kv_heads={self.n_kv_heads}"
        )

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim


@dataclass(frozen=True)
class MoESpec:
    """Sparsely-gated expert FFN spec (Eq. 1-2 of the paper)."""

    num_experts: int
    top_k: int
    d_ff: int  # per-expert hidden dim
    num_shared: int = 0  # always-resident shared experts (DeepSeekMoE)
    shared_d_ff: int = 0  # fused hidden dim of the shared expert block
    capacity_factor: float = 1.25
    router_softcap: Optional[float] = None

    def __post_init__(self):
        assert 0 < self.top_k <= self.num_experts

    def capacity(self, n_tokens: int) -> int:
        """GShard-style per-expert capacity."""
        cap = int(math.ceil(n_tokens * self.top_k / self.num_experts * self.capacity_factor))
        return max(cap, self.top_k)


@dataclass(frozen=True)
class SSMSpec:
    """Mamba2 / SSD spec (state-space duality, arXiv:2405.21060)."""

    d_state: int
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 128
    n_groups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        di = self.d_inner(d_model)
        assert di % self.head_dim == 0
        return di // self.head_dim


@dataclass(frozen=True)
class BlockSpec:
    """One transformer-ish block: pre-norm + mixer + pre-norm + channel-mixer."""

    kind: str  # "attn_dense" | "attn_moe" | "mamba" | "shared_attn"
    attn: Optional[AttnSpec] = None
    d_ff: int = 0  # dense (gated) MLP hidden dim; 0 => no MLP (pure mamba block)
    moe: Optional[MoESpec] = None
    ssm: Optional[SSMSpec] = None

    def __post_init__(self):
        if self.kind in ("attn_dense", "shared_attn"):
            assert self.attn is not None
        if self.kind == "attn_moe":
            assert self.attn is not None and self.moe is not None
        if self.kind == "mamba":
            assert self.ssm is not None


@dataclass(frozen=True)
class LayoutGroup:
    """``pattern`` applied ``repeats`` times, scanned over repeats."""

    pattern: Tuple[str, ...]
    repeats: int

    @property
    def n_layers(self) -> int:
        return len(self.pattern) * self.repeats


@dataclass(frozen=True)
class MelinoeSpec:
    """Hyper-parameters of the paper's technique (Sec 3.1, App B.2)."""

    enabled: bool = True
    cache_capacity: int = 0  # C; 0 => default E // 4
    gamma: float = 0.9
    rho: float = 0.1
    lambda_cs: float = 0.5
    lambda_rm: float = 0.1
    request_mode: str = "soft"  # "soft" | "hard_st"
    base_router_mode: str = "same_trajectory"  # | "exact"
    lora_rank: int = 32
    lora_alpha: float = 16.0
    rm_token_chunk: int = 128  # token chunking for the O(E^2) rank loss
    uniform_cache_init: bool = True  # skip the cache-fill phase (Sec 3.1.1)
    cs_impl: str = "scan"  # paper-faithful sequential | "assoc" (log-depth, §Perf)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense|moe|ssm|hybrid|vlm|audio
    d_model: int
    vocab: int
    block_defs: Mapping[str, BlockSpec]
    layout: Tuple[LayoutGroup, ...]
    norm_eps: float = 1e-6
    logit_softcap: Optional[float] = None
    tie_embeddings: bool = False
    embed_scale: bool = False  # multiply embeddings by sqrt(d_model) (gemma)
    prefix_len: int = 0  # frontend stub embeddings prepended (vlm/audio)
    max_seq_len: int = 524_288
    dtype: str = "bfloat16"
    long_context_window: int = 8192  # sliding window used for long_500k variants
    melinoe: Optional[MelinoeSpec] = None
    source: str = ""  # citation for the config

    # ---- derived -----------------------------------------------------
    @property
    def n_layers(self) -> int:
        return sum(g.n_layers for g in self.layout)

    def blocks_in_order(self) -> Tuple[str, ...]:
        out = []
        for g in self.layout:
            out.extend(list(g.pattern) * g.repeats)
        return tuple(out)

    @property
    def moe_spec(self) -> Optional[MoESpec]:
        for b in self.block_defs.values():
            if b.moe is not None:
                return b.moe
        return None

    @property
    def n_moe_layers(self) -> int:
        return sum(1 for k in self.blocks_in_order() if self.block_defs[k].moe is not None)

    @property
    def has_router(self) -> bool:
        return self.n_moe_layers > 0

    def melinoe_cache_capacity(self) -> int:
        spec = self.moe_spec
        assert spec is not None
        if self.melinoe and self.melinoe.cache_capacity:
            return self.melinoe.cache_capacity
        return max(spec.top_k, spec.num_experts // 4)

    def validate(self) -> None:
        for g in self.layout:
            for name in g.pattern:
                assert name in self.block_defs, f"unknown block {name!r}"
        assert self.n_layers > 0

    # ---- parameter counting (for roofline MODEL_FLOPS) ----------------
    def param_counts(self) -> dict:
        """Returns dict with total / active parameter counts (analytic)."""
        d = self.d_model
        total = self.vocab * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab * d  # lm head
        active = total
        for name in self.blocks_in_order():
            b = self.block_defs[name]
            t = a = 0
            if b.attn is not None:
                s = b.attn
                attn_p = d * s.q_dim + 2 * d * s.kv_dim + s.q_dim * d
                if s.qk_norm:
                    attn_p += 2 * s.head_dim
                t += attn_p
                a += attn_p
            if b.d_ff:
                mlp_p = 3 * d * b.d_ff
                t += mlp_p
                a += mlp_p
            if b.moe is not None:
                m = b.moe
                t += m.num_experts * 3 * d * m.d_ff + m.num_experts * d  # experts + router
                a += m.top_k * 3 * d * m.d_ff + m.num_experts * d
                if m.shared_d_ff:
                    t += 3 * d * m.shared_d_ff
                    a += 3 * d * m.shared_d_ff
            if b.ssm is not None:
                s = b.ssm
                di = s.d_inner(d)
                nh = s.n_heads(d)
                conv_dim = di + 2 * s.n_groups * s.d_state
                ssm_p = (
                    d * (2 * di + 2 * s.n_groups * s.d_state + nh)  # in_proj (z,x,B,C,dt)
                    + conv_dim * s.d_conv  # conv1d
                    + 2 * nh  # A_log, D
                    + di  # gated norm
                    + di * d  # out_proj
                )
                t += ssm_p
                a += ssm_p
            # two / three pre-norms per block
            t += 2 * d
            a += 2 * d
            total += t
            active += a
        return {"total": total, "active": active}


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


SHAPES: Mapping[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Smoke-test reduction
# ---------------------------------------------------------------------------


def make_smoke(cfg: ModelConfig, *, d_model: int = 128, vocab: int = 512) -> ModelConfig:
    """Reduced variant of the same family: <=2 pattern blocks, 1 repeat,
    d_model<=512, <=4 experts. Used by per-arch CPU smoke tests."""

    def shrink_attn(a: Optional[AttnSpec]) -> Optional[AttnSpec]:
        if a is None:
            return None
        return replace(
            a, n_heads=4, n_kv_heads=2 if a.n_kv_heads < a.n_heads else 4, head_dim=32
        )

    def shrink_block(b: BlockSpec) -> BlockSpec:
        moe = None
        if b.moe is not None:
            moe = replace(
                b.moe,
                num_experts=4,
                top_k=min(b.moe.top_k, 2),
                d_ff=64,
                shared_d_ff=64 if b.moe.shared_d_ff else 0,
                capacity_factor=2.0,
            )
        ssm = None
        if b.ssm is not None:
            ssm = replace(b.ssm, d_state=16, head_dim=32, chunk=32)
        return BlockSpec(
            kind=b.kind,
            attn=shrink_attn(b.attn),
            d_ff=256 if b.d_ff else 0,
            moe=moe,
            ssm=ssm,
        )

    block_defs = {k: shrink_block(v) for k, v in cfg.block_defs.items()}
    # keep one block of each distinct kind across the WHOLE layout (so e.g.
    # deepseek keeps its MoE block even though layer 0 is dense), up to 3;
    # duplicate a single-kind pattern to 2 layers.
    seen, kept = set(), []
    for p in cfg.blocks_in_order():
        if p not in seen:
            kept.append(p)
            seen.add(p)
        if len(kept) == 3:
            break
    pattern = tuple(kept) if len(kept) > 1 else (kept[0], kept[0])
    layout = (LayoutGroup(pattern, 1),)
    mel = cfg.melinoe
    if mel is not None:
        mel = replace(mel, cache_capacity=0, lora_rank=4, rm_token_chunk=32)
    return replace(
        cfg,
        name=cfg.name + "-smoke",
        d_model=d_model,
        vocab=vocab,
        block_defs=block_defs,
        layout=layout,
        prefix_len=min(cfg.prefix_len, 8),
        max_seq_len=1024,
        melinoe=mel,
        tie_embeddings=cfg.tie_embeddings,
    )
