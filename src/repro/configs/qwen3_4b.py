"""qwen3-4b [dense]: qk_norm, GQA [hf:Qwen/Qwen3-8B family].
36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936."""
from .base import AttnSpec, BlockSpec, LayoutGroup, ModelConfig
from .registry import register


@register("qwen3-4b")
def config() -> ModelConfig:
    attn = AttnSpec(n_heads=32, n_kv_heads=8, head_dim=128, qk_norm=True, rope_theta=1e6)
    return ModelConfig(
        name="qwen3-4b",
        family="dense",
        d_model=2560,
        vocab=151_936,
        block_defs={"dense": BlockSpec(kind="attn_dense", attn=attn, d_ff=9728)},
        layout=(LayoutGroup(("dense",), 36),),
        tie_embeddings=True,
        source="hf:Qwen/Qwen3-8B",
    )
