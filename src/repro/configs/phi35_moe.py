"""Phi-3.5-MoE (paper backbone, Table 6): 32L, 16 experts/layer, top-2,
42B total / 6.6B active [arXiv:2404.14219]."""
from .base import AttnSpec, BlockSpec, LayoutGroup, MelinoeSpec, ModelConfig, MoESpec
from .registry import register


@register("phi35-moe")
def config() -> ModelConfig:
    attn = AttnSpec(n_heads=32, n_kv_heads=8, head_dim=128)
    moe = MoESpec(num_experts=16, top_k=2, d_ff=6400)
    return ModelConfig(
        name="phi35-moe",
        family="moe",
        d_model=4096,
        vocab=32_064,
        block_defs={"moe": BlockSpec(kind="attn_moe", attn=attn, moe=moe)},
        layout=(LayoutGroup(("moe",), 32),),
        melinoe=MelinoeSpec(cache_capacity=4),  # paper Table 7: C=4 (E/4)
        source="paper Table 6 / Phi-3.5-MoE",
    )
