"""OLMoE-1B-7B (paper backbone, Table 6): 16L, 64 experts/layer, top-8,
6.9B total / 1.3B active [openreview:xXTkbTBmqq]."""
from .base import AttnSpec, BlockSpec, LayoutGroup, MelinoeSpec, ModelConfig, MoESpec
from .registry import register


@register("olmoe")
def config() -> ModelConfig:
    attn = AttnSpec(n_heads=16, n_kv_heads=16, head_dim=128, qk_norm=True)
    moe = MoESpec(num_experts=64, top_k=8, d_ff=1024)
    return ModelConfig(
        name="olmoe",
        family="moe",
        d_model=2048,
        vocab=50_304,
        block_defs={"moe": BlockSpec(kind="attn_moe", attn=attn, moe=moe)},
        layout=(LayoutGroup(("moe",), 16),),
        melinoe=MelinoeSpec(cache_capacity=16),  # C=16 per the paper (E/4)
        source="paper Table 6 / OLMoE",
    )
