"""musicgen-medium [audio]: decoder-only over EnCodec tokens [arXiv:2306.05284].

48L d_model=1536 24H (GQA kv=24) d_ff=6144 vocab=2048. The EnCodec /
conditioning frontend is a stub: ``input_specs`` provides a precomputed
conditioning ``prefix_embed`` (B, 64, d_model).
"""
from .base import AttnSpec, BlockSpec, LayoutGroup, ModelConfig
from .registry import register


@register("musicgen-medium")
def config() -> ModelConfig:
    attn = AttnSpec(n_heads=24, n_kv_heads=24, head_dim=64)
    return ModelConfig(
        name="musicgen-medium",
        family="audio",
        d_model=1536,
        vocab=2048,
        block_defs={"dense": BlockSpec(kind="attn_dense", attn=attn, d_ff=6144)},
        layout=(LayoutGroup(("dense",), 48),),
        prefix_len=64,
        source="arXiv:2306.05284",
    )
