"""gemma2-27b [dense]: local+global alternating attention, logit softcap
[arXiv:2408.00118]. 46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000."""
from .base import AttnSpec, BlockSpec, LayoutGroup, ModelConfig
from .registry import register


@register("gemma2-27b")
def config() -> ModelConfig:
    local = AttnSpec(
        n_heads=32, n_kv_heads=16, head_dim=128, window=4096, attn_softcap=50.0
    )
    glob = AttnSpec(n_heads=32, n_kv_heads=16, head_dim=128, attn_softcap=50.0)
    return ModelConfig(
        name="gemma2-27b",
        family="dense",
        d_model=4608,
        vocab=256_000,
        block_defs={
            "local": BlockSpec(kind="attn_dense", attn=local, d_ff=36_864),
            "global": BlockSpec(kind="attn_dense", attn=glob, d_ff=36_864),
        },
        layout=(LayoutGroup(("local", "global"), 23),),
        logit_softcap=30.0,
        tie_embeddings=True,
        embed_scale=True,
        source="arXiv:2408.00118",
    )
