"""command-r-plus-104b [dense]: GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01].
64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000."""
from .base import AttnSpec, BlockSpec, LayoutGroup, ModelConfig
from .registry import register


@register("command-r-plus-104b")
def config() -> ModelConfig:
    attn = AttnSpec(n_heads=96, n_kv_heads=8, head_dim=128)
    return ModelConfig(
        name="command-r-plus-104b",
        family="dense",
        d_model=12_288,
        vocab=256_000,
        block_defs={"dense": BlockSpec(kind="attn_dense", attn=attn, d_ff=33_792)},
        layout=(LayoutGroup(("dense",), 64),),
        source="hf:CohereForAI/c4ai-command-r-v01",
    )
