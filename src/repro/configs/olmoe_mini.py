"""olmoe-mini: the CPU-scale reproduction workhorse (~100M params).

Same family as OLMoE (fine-grained MoE, qk-norm attention) at a scale a
CPU can fine-tune for a few hundred steps. Used by the end-to-end
example driver and the paper-claim benchmarks.
"""
from .base import AttnSpec, BlockSpec, LayoutGroup, MelinoeSpec, ModelConfig, MoESpec
from .registry import register


@register("olmoe-mini")
def config() -> ModelConfig:
    attn = AttnSpec(n_heads=8, n_kv_heads=8, head_dim=32, qk_norm=True)
    moe = MoESpec(num_experts=32, top_k=4, d_ff=512, capacity_factor=2.0)
    return ModelConfig(
        name="olmoe-mini",
        family="moe",
        d_model=256,
        vocab=4096,
        block_defs={"moe": BlockSpec(kind="attn_moe", attn=attn, moe=moe)},
        layout=(LayoutGroup(("moe",), 8),),
        max_seq_len=2048,
        melinoe=MelinoeSpec(cache_capacity=8, lora_rank=8),  # C = E/4
        source="reduced OLMoE for CPU reproduction",
    )
