"""mamba2-130m [ssm]: SSD (state-space duality) [arXiv:2405.21060].
24L d_model=768 (attn-free) vocab=50280, ssm_state=128."""
from .base import BlockSpec, LayoutGroup, ModelConfig, SSMSpec
from .registry import register


@register("mamba2-130m")
def config() -> ModelConfig:
    ssm = SSMSpec(d_state=128, d_conv=4, expand=2, head_dim=64)
    return ModelConfig(
        name="mamba2-130m",
        family="ssm",
        d_model=768,
        vocab=50_280,
        block_defs={"mamba": BlockSpec(kind="mamba", ssm=ssm)},
        layout=(LayoutGroup(("mamba",), 24),),
        tie_embeddings=True,
        source="arXiv:2405.21060",
    )
