"""granite-moe-1b-a400m [moe]: 32 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base].
24L d_model=1024 16H (GQA kv=8) d_ff=512 vocab=49155, MoE 32e top-8.

MELINOE applies directly (has a router): C = E/4 = 8 by default.
"""
from .base import AttnSpec, BlockSpec, LayoutGroup, MelinoeSpec, ModelConfig, MoESpec
from .registry import register


@register("granite-moe-1b-a400m")
def config() -> ModelConfig:
    attn = AttnSpec(n_heads=16, n_kv_heads=8, head_dim=64)
    moe = MoESpec(num_experts=32, top_k=8, d_ff=512)
    return ModelConfig(
        name="granite-moe-1b-a400m",
        family="moe",
        d_model=1024,
        vocab=49_155,
        block_defs={"moe": BlockSpec(kind="attn_moe", attn=attn, moe=moe)},
        layout=(LayoutGroup(("moe",), 24),),
        melinoe=MelinoeSpec(),
        source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    )
