"""Mixtral-8x7B (paper backbone, Table 6): 32L, 8 experts/layer, top-2,
46.7B total / 12.9B active [arXiv:2401.04088]."""
from .base import AttnSpec, BlockSpec, LayoutGroup, MelinoeSpec, ModelConfig, MoESpec
from .registry import register


@register("mixtral-8x7b")
def config() -> ModelConfig:
    attn = AttnSpec(n_heads=32, n_kv_heads=8, head_dim=128)
    moe = MoESpec(num_experts=8, top_k=2, d_ff=14_336)
    return ModelConfig(
        name="mixtral-8x7b",
        family="moe",
        d_model=4096,
        vocab=32_000,
        block_defs={"moe": BlockSpec(kind="attn_moe", attn=attn, moe=moe)},
        layout=(LayoutGroup(("moe",), 32),),
        melinoe=MelinoeSpec(cache_capacity=2),  # paper Table 7: C=2 (E/4)
        source="paper Table 6 / Mixtral",
    )
