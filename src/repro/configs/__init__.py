from .base import (
    SHAPES,
    AttnSpec,
    BlockSpec,
    LayoutGroup,
    MelinoeSpec,
    ModelConfig,
    MoESpec,
    ShapeSpec,
    SSMSpec,
    make_smoke,
)
from .registry import ASSIGNED, PAPER, get_config, list_archs

__all__ = [
    "SHAPES",
    "AttnSpec",
    "BlockSpec",
    "LayoutGroup",
    "MelinoeSpec",
    "ModelConfig",
    "MoESpec",
    "ShapeSpec",
    "SSMSpec",
    "make_smoke",
    "ASSIGNED",
    "PAPER",
    "get_config",
    "list_archs",
]
