"""stablelm-12b [dense] [hf:stabilityai/stablelm-2-1_6b family].
40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352."""
from .base import AttnSpec, BlockSpec, LayoutGroup, ModelConfig
from .registry import register


@register("stablelm-12b")
def config() -> ModelConfig:
    attn = AttnSpec(n_heads=32, n_kv_heads=8, head_dim=160)
    return ModelConfig(
        name="stablelm-12b",
        family="dense",
        d_model=5120,
        vocab=100_352,
        block_defs={"dense": BlockSpec(kind="attn_dense", attn=attn, d_ff=13_824)},
        layout=(LayoutGroup(("dense",), 40),),
        source="hf:stabilityai/stablelm-2-1_6b",
    )
