"""Synthetic cluster-preference LM corpus.

Dolly15K / GSM8K are unavailable offline, so the paper's premise is
engineered directly into the data (DESIGN.md Sec 10): sequences are
drawn from latent *clusters*, each with its own token distribution and
phrase bank. A base MoE trained on this corpus develops weak
per-sequence expert preferences (clusters route differently), which is
exactly the structure MELINOE's fine-tuning amplifies — mirroring the
paper's Fig 1b observation on OLMoE.

Deterministic, seeded, infinite; batches shard over the mesh data axes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class SyntheticConfig:
    vocab: int = 4096
    n_clusters: int = 8
    seq_len: int = 128
    cluster_vocab_frac: float = 0.22  # token budget each cluster prefers
    phrase_len: int = 8
    n_phrases: int = 64  # learnable n-gram structure per cluster
    phrase_prob: float = 0.6
    seed: int = 0


class ClusterLM:
    """Markov-ish generator: cluster-specific unigram pools + phrase bank."""

    def __init__(self, cfg: SyntheticConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V, K = cfg.vocab, cfg.n_clusters
        nv = max(int(V * cfg.cluster_vocab_frac), 16)
        self.pools = np.stack([rng.choice(V, nv, replace=False) for _ in range(K)])
        self.phrases = rng.integers(
            0, V, (K, cfg.n_phrases, cfg.phrase_len), dtype=np.int64
        )
        for k in range(K):  # phrases drawn from the cluster pool
            self.phrases[k] = self.pools[k][
                rng.integers(0, nv, (cfg.n_phrases, cfg.phrase_len))
            ]

    def sample_sequence(self, rng: np.random.Generator,
                        cluster: Optional[int] = None) -> Tuple[np.ndarray, int]:
        cfg = self.cfg
        k = int(rng.integers(cfg.n_clusters)) if cluster is None else cluster
        out = np.empty(cfg.seq_len, np.int64)
        i = 0
        while i < cfg.seq_len:
            if rng.random() < cfg.phrase_prob:
                ph = self.phrases[k][rng.integers(cfg.n_phrases)]
                n = min(len(ph), cfg.seq_len - i)
                out[i : i + n] = ph[:n]
                i += n
            else:
                out[i] = self.pools[k][rng.integers(self.pools.shape[1])]
                i += 1
        return out, k

    def batches(self, batch_size: int, *, seed: int = 1,
                with_cluster: bool = False) -> Iterator:
        rng = np.random.default_rng(seed)
        while True:
            toks = np.empty((batch_size, self.cfg.seq_len), np.int64)
            ks = np.empty((batch_size,), np.int64)
            for b in range(batch_size):
                toks[b], ks[b] = self.sample_sequence(rng)
            batch = {
                "tokens": toks.astype(np.int32),
                "labels": toks.astype(np.int32),
            }
            if with_cluster:
                batch["cluster"] = ks
            yield batch


def eval_batches(lm: ClusterLM, n: int, batch_size: int, *, seed: int = 999):
    """Deterministic held-out split."""
    it = lm.batches(batch_size, seed=seed, with_cluster=True)
    return [next(it) for _ in range(n)]
