"""Observability subsystem: structured tracing, labeled metrics, and
Eq.-3 model-vs-measurement reconciliation.

Layers:
  trace.py      — nested spans + instant events (perf_counter), no-op
                  singleton when disabled, Chrome-trace/JSONL exporters,
                  optional jax.profiler.TraceAnnotation pass-through
  registry.py   — labeled counters/gauges/histograms, snapshot/diff,
                  JSON + Prometheus text export
  reconcile.py  — measured per-layer fetch/compute/overlap vs the
                  modeled serial/overlapped Eq.-3 clocks
  validate.py   — Chrome trace-event schema validator (CLI for CI)

Enable tracing programmatically (``enable_tracing()``) or with the
``REPRO_TRACE=1`` environment variable; disabled tracing costs one
attribute check on the hot paths.
"""
from .registry import REGISTRY, Counter, Gauge, Histogram, MetricsRegistry
from .reconcile import (
    LayerReconciliation,
    ReconciliationReport,
    reconcile,
)
from .trace import (
    NULL_TRACER,
    InstantRecord,
    NullTracer,
    SpanRecord,
    Tracer,
    chrome_trace,
    clock_span,
    disable_tracing,
    enable_tracing,
    get_tracer,
)
from .validate import validate_chrome_trace

__all__ = [
    "REGISTRY",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "LayerReconciliation",
    "ReconciliationReport",
    "reconcile",
    "NULL_TRACER",
    "InstantRecord",
    "NullTracer",
    "SpanRecord",
    "Tracer",
    "chrome_trace",
    "clock_span",
    "disable_tracing",
    "enable_tracing",
    "get_tracer",
    "validate_chrome_trace",
]
