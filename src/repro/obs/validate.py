"""Chrome trace-event JSON validator (exporter schema).

Used by tests and the CI trace-smoke step to guarantee emitted traces
stay Perfetto-loadable:

    PYTHONPATH=src python -m repro.obs.validate trace.json [more.json...]

Exits non-zero with one line per violation otherwise.
"""
from __future__ import annotations

import json
import sys
from typing import Any, List

VALID_PH = {"X", "B", "E", "i", "I", "M", "C"}


def validate_chrome_trace(obj: Any, *, require_events: bool = True) -> List[str]:
    """Return a list of schema violations (empty == valid).

    Accepts both container forms Chrome/Perfetto load: a dict with a
    ``traceEvents`` list, or a bare event list.
    """
    errs: List[str] = []
    if isinstance(obj, dict):
        events = obj.get("traceEvents")
        if not isinstance(events, list):
            return ["top-level dict has no 'traceEvents' list"]
    elif isinstance(obj, list):
        events = obj
    else:
        return [f"expected dict or list at top level, got {type(obj).__name__}"]

    n_real = 0
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errs.append(f"{where}: not an object")
            continue
        name = ev.get("name")
        if not isinstance(name, str) or not name:
            errs.append(f"{where}: missing/empty 'name'")
        ph = ev.get("ph")
        if ph not in VALID_PH:
            errs.append(f"{where}: bad phase {ph!r} (allowed: {sorted(VALID_PH)})")
            continue
        if not isinstance(ev.get("ts"), (int, float)) or ev["ts"] < 0:
            errs.append(f"{where}: 'ts' must be a number >= 0")
        for k in ("pid", "tid"):
            if not isinstance(ev.get(k), int):
                errs.append(f"{where}: '{k}' must be an int")
        if ph == "X":
            if not isinstance(ev.get("dur"), (int, float)) or ev["dur"] < 0:
                errs.append(f"{where}: complete event needs 'dur' >= 0")
            n_real += 1
        elif ph in ("i", "I"):
            n_real += 1
        args = ev.get("args")
        if args is not None and not isinstance(args, dict):
            errs.append(f"{where}: 'args' must be an object")
    if require_events and n_real == 0:
        errs.append("trace contains no span/instant events")
    return errs


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv:
        print("usage: python -m repro.obs.validate <trace.json> [...]")
        return 2
    bad = 0
    for path in argv:
        try:
            with open(path) as f:
                obj = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"{path}: unreadable/invalid JSON: {e}")
            bad += 1
            continue
        errs = validate_chrome_trace(obj)
        if errs:
            bad += 1
            for e in errs[:20]:
                print(f"{path}: {e}")
            if len(errs) > 20:
                print(f"{path}: ... {len(errs) - 20} more")
        else:
            n = len(obj["traceEvents"]) if isinstance(obj, dict) else len(obj)
            print(f"{path}: OK ({n} events)")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
