"""Labeled metrics: counters, gauges, histograms with snapshot/diff.

A deliberately small Prometheus-shaped surface: metrics are identified
by ``(name, sorted labels)``, instruments are get-or-create so call
sites never coordinate, and the registry exports both JSON (for bench
reports) and Prometheus text exposition (for scraping). ``snapshot()``
returns a flat ``{key: float}`` dict and ``diff()`` subtracts two
snapshots, so "what did this request/trial cost" is one dict diff —
the same pattern ``EngineMetrics``/``ServerMetrics`` already use for
their scalar counters, generalized.
"""
from __future__ import annotations

import json
import math
import threading
from typing import Dict, List, Optional, Sequence, Tuple

DEFAULT_BUCKETS = (
    1e-5, 1e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)


def _label_key(labels: Dict[str, object]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _fmt_labels(labels: Tuple[Tuple[str, str], ...]) -> str:
    if not labels:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in labels) + "}"


class Counter:
    """Monotonically increasing value."""

    kind = "counter"

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...]):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def samples(self) -> List[Tuple[str, float]]:
        return [(self.name + _fmt_labels(self.labels), self.value)]


class Gauge(Counter):
    """Value that can go anywhere (set wins over inc)."""

    kind = "gauge"

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Cumulative-bucket histogram (Prometheus semantics: each bucket
    counts observations <= its upper bound; +Inf bucket == count)."""

    kind = "histogram"

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...],
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        self.name = name
        self.labels = labels
        self.bounds = tuple(sorted(buckets))
        self.counts = [0] * (len(self.bounds) + 1)  # +Inf last
        self.total = 0
        self.sum = 0.0

    def observe(self, v: float) -> None:
        v = float(v)
        self.total += 1
        self.sum += v
        for i, b in enumerate(self.bounds):
            if v <= b:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    def samples(self) -> List[Tuple[str, float]]:
        out = []
        cum = 0
        for b, c in zip(self.bounds, self.counts[:-1]):
            cum += c
            lab = self.labels + (("le", repr(float(b))),)
            out.append((f"{self.name}_bucket" + _fmt_labels(lab), float(cum)))
        lab = self.labels + (("le", "+Inf"),)
        out.append((f"{self.name}_bucket" + _fmt_labels(lab), float(self.total)))
        out.append((f"{self.name}_count" + _fmt_labels(self.labels),
                    float(self.total)))
        out.append((f"{self.name}_sum" + _fmt_labels(self.labels), self.sum))
        return out


class MetricsRegistry:
    """Get-or-create home for labeled instruments.

    One global :data:`REGISTRY` serves the repo (servers and engines
    publish onto it); tests construct private registries.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[Tuple[str, Tuple[Tuple[str, str], ...]], object] = {}
        self._help: Dict[str, str] = {}

    def _get(self, cls, name: str, help: str, labels: Dict[str, object],
             **kw):
        key = (name, _label_key(labels))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = self._metrics[key] = cls(name, key[1], **kw)
                if help:
                    self._help.setdefault(name, help)
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {type(m).__name__}")
            return m

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_BUCKETS,
                  **labels) -> Histogram:
        return self._get(Histogram, name, help, labels, buckets=buckets)

    # -- export ------------------------------------------------------------
    def snapshot(self) -> Dict[str, float]:
        """Flat ``{"name{label=...}": value}`` over every sample (bucket
        rows included), suitable for JSON dumps and :meth:`diff`."""
        with self._lock:
            metrics = list(self._metrics.values())
        out: Dict[str, float] = {}
        for m in metrics:
            out.update(m.samples())
        return out

    @staticmethod
    def diff(new: Dict[str, float], old: Dict[str, float]) -> Dict[str, float]:
        """new - old per key; keys only in ``new`` diff against 0."""
        return {k: v - old.get(k, 0.0) for k, v in new.items()}

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)

    def to_prometheus_text(self) -> str:
        """Prometheus text exposition format (0.0.4)."""
        with self._lock:
            metrics = list(self._metrics.values())
            help_ = dict(self._help)
        by_name: Dict[str, List] = {}
        for m in metrics:
            by_name.setdefault(m.name, []).append(m)
        lines: List[str] = []
        for name in sorted(by_name):
            group = by_name[name]
            if name in help_:
                lines.append(f"# HELP {name} {help_[name]}")
            lines.append(f"# TYPE {name} {group[0].kind}")
            for m in group:
                for key, val in m.samples():
                    if math.isnan(val):  # pragma: no cover - defensive
                        val = 0.0
                    lines.append(f"{key} {val:g}")
        return "\n".join(lines) + "\n"

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()
            self._help.clear()


REGISTRY = MetricsRegistry()
