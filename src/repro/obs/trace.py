"""Structured tracing: nested spans + instant events over perf_counter.

The repo's latency story (Eq. 3: expert transfers hidden under compute)
was previously *asserted* by a modeled clock; this module records where
the time actually goes so ``obs.reconcile`` can check the model against
measurement.

Design constraints, in order:

* **Zero overhead when disabled.** The module-global tracer defaults to
  :data:`NULL_TRACER`; hot paths either guard on ``tracer.enabled``
  (one attribute check) or call :meth:`NullTracer.span`, which returns a
  shared no-op context manager without touching any buffer.
* **Nested spans.** ``with tracer.span("decode_layer", layer=3):``
  records (name, start, duration, thread, depth, attrs). Depth comes
  from a per-thread stack, so spans nest correctly across threads.
* **Exporters.** Chrome trace-event JSON (``ph="X"`` complete events,
  microsecond timestamps — loads directly in Perfetto / chrome://tracing)
  and line-per-record JSONL.
* **Always-timed spans.** :class:`clock_span` measures with
  ``perf_counter`` regardless of tracing state and exposes ``.dur`` —
  the serving clocks consume that, so the ad-hoc ``t0 = perf_counter()``
  pairs collapse into the same spans the trace records.

Optional ``jax.profiler.TraceAnnotation`` pass-through: when a tracer is
created with ``jax_annotations=True``, every span also opens an XLA
profiler annotation, so spans line up inside ``jax.profiler`` captures.
"""
from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

try:  # pragma: no cover - present in every supported JAX
    from jax.profiler import TraceAnnotation as _JaxAnnotation
except Exception:  # pragma: no cover
    _JaxAnnotation = None


# ---------------------------------------------------------------------------
# Records
# ---------------------------------------------------------------------------


@dataclass
class SpanRecord:
    """One completed span. Times are ``perf_counter`` seconds."""

    name: str
    t0: float
    dur: float
    tid: int
    depth: int
    args: Dict[str, Any] = field(default_factory=dict)

    @property
    def t1(self) -> float:
        return self.t0 + self.dur


@dataclass
class InstantRecord:
    """A point event (cache miss, retirement, dispatch decision...)."""

    name: str
    t0: float
    tid: int
    args: Dict[str, Any] = field(default_factory=dict)


def _jsonable(v: Any) -> Any:
    """Coerce span-arg values to JSON-native types (numpy scalars show
    up constantly in this codebase)."""
    if isinstance(v, (str, bool, int, float)) or v is None:
        return v
    try:
        import numpy as np

        if isinstance(v, np.integer):
            return int(v)
        if isinstance(v, np.floating):
            return float(v)
    except Exception:  # pragma: no cover
        pass
    return str(v)


# ---------------------------------------------------------------------------
# Tracers
# ---------------------------------------------------------------------------


class _SpanCtx:
    """Context manager for one live span on the real tracer."""

    __slots__ = ("_tr", "name", "args", "t0", "dur", "_depth", "_jax")

    def __init__(self, tr: "Tracer", name: str, args: Dict[str, Any]):
        self._tr = tr
        self.name = name
        self.args = args
        self.t0 = 0.0
        self.dur = 0.0
        self._depth = 0
        self._jax = None

    def __enter__(self) -> "_SpanCtx":
        tr = self._tr
        stack = tr._stack()
        self._depth = len(stack)
        stack.append(self)
        if tr.jax_annotations and _JaxAnnotation is not None:
            self._jax = _JaxAnnotation(self.name)
            self._jax.__enter__()
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self.dur = time.perf_counter() - self.t0
        tr = self._tr
        if self._jax is not None:
            self._jax.__exit__(*exc)
        stack = tr._stack()
        if stack and stack[-1] is self:
            stack.pop()
        tr._append_span(
            SpanRecord(self.name, self.t0, self.dur,
                       threading.get_ident(), self._depth, self.args))


class _NullCtx:
    """Shared no-op context manager: the cost of a disabled span."""

    __slots__ = ()
    name = ""
    dur = 0.0
    t0 = 0.0

    def __enter__(self) -> "_NullCtx":
        return self

    def __exit__(self, *exc) -> None:
        pass


_NULL_CTX = _NullCtx()


class NullTracer:
    """Disabled tracing: every operation is a no-op, nothing is stored.
    Hot paths may guard on :attr:`enabled` (a class attribute, so the
    check is one attribute load) to skip even argument construction."""

    enabled = False
    jax_annotations = False

    def span(self, name: str, **args) -> _NullCtx:
        return _NULL_CTX

    def instant(self, name: str, **args) -> None:
        pass

    def spans(self) -> List[SpanRecord]:
        return []

    def instants(self) -> List[InstantRecord]:
        return []

    def drain(self):
        return [], []

    def clear(self) -> None:
        pass


class Tracer:
    """In-memory span/instant recorder with a bounded buffer.

    Thread safety: records append under a lock; the per-thread nesting
    stack lives in a ``threading.local``. When ``max_records`` is hit the
    oldest half of the buffer is dropped (and counted) rather than
    growing without bound in long-lived servers.
    """

    enabled = True

    def __init__(self, *, jax_annotations: bool = False,
                 max_records: int = 1_000_000):
        self.jax_annotations = jax_annotations
        self.max_records = max_records
        self.dropped = 0
        self._lock = threading.Lock()
        self._spans: List[SpanRecord] = []
        self._instants: List[InstantRecord] = []
        self._local = threading.local()

    # -- recording ---------------------------------------------------------
    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def span(self, name: str, **args) -> _SpanCtx:
        return _SpanCtx(self, name, args)

    def instant(self, name: str, **args) -> None:
        rec = InstantRecord(name, time.perf_counter(),
                            threading.get_ident(), args)
        with self._lock:
            self._instants.append(rec)
            if len(self._instants) > self.max_records:
                drop = len(self._instants) // 2
                del self._instants[:drop]
                self.dropped += drop

    def _append_span(self, rec: SpanRecord) -> None:
        with self._lock:
            self._spans.append(rec)
            if len(self._spans) > self.max_records:
                drop = len(self._spans) // 2
                del self._spans[:drop]
                self.dropped += drop

    # -- access ------------------------------------------------------------
    def spans(self) -> List[SpanRecord]:
        with self._lock:
            return list(self._spans)

    def instants(self) -> List[InstantRecord]:
        with self._lock:
            return list(self._instants)

    def drain(self):
        """Return (spans, instants) and clear the buffers."""
        with self._lock:
            s, i = self._spans, self._instants
            self._spans, self._instants = [], []
        return s, i

    def clear(self) -> None:
        self.drain()

    # -- export ------------------------------------------------------------
    def to_chrome_trace(self, *, process_name: str = "repro") -> Dict[str, Any]:
        return chrome_trace(self.spans(), self.instants(),
                            process_name=process_name)

    def export_chrome_trace(self, path, *, process_name: str = "repro") -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(process_name=process_name), f)

    def export_jsonl(self, path) -> None:
        with open(path, "w") as f:
            for s in self.spans():
                f.write(json.dumps({
                    "kind": "span", "name": s.name, "t0": s.t0,
                    "dur": s.dur, "tid": s.tid, "depth": s.depth,
                    "args": {k: _jsonable(v) for k, v in s.args.items()},
                }) + "\n")
            for i in self.instants():
                f.write(json.dumps({
                    "kind": "instant", "name": i.name, "t0": i.t0,
                    "tid": i.tid,
                    "args": {k: _jsonable(v) for k, v in i.args.items()},
                }) + "\n")


def chrome_trace(spans: List[SpanRecord],
                 instants: Optional[List[InstantRecord]] = None,
                 *, process_name: str = "repro") -> Dict[str, Any]:
    """Records -> Chrome trace-event JSON (Perfetto-loadable).

    Spans become ``ph="X"`` complete events; instants become ``ph="i"``.
    Timestamps are microseconds relative to the earliest record, so the
    trace opens at t=0 in the viewer.
    """
    pid = os.getpid()
    events: List[Dict[str, Any]] = [{
        "name": "process_name", "ph": "M", "pid": pid, "tid": 0, "ts": 0,
        "args": {"name": process_name},
    }]
    all_t0 = [s.t0 for s in spans] + [i.t0 for i in (instants or [])]
    base = min(all_t0) if all_t0 else 0.0
    tids: Dict[int, int] = {}

    def tid_of(raw: int) -> int:
        if raw not in tids:
            tids[raw] = len(tids)
            events.append({"name": "thread_name", "ph": "M", "pid": pid,
                           "tid": tids[raw], "ts": 0,
                           "args": {"name": f"thread-{len(tids) - 1}"}})
        return tids[raw]

    for s in spans:
        events.append({
            "name": s.name, "ph": "X", "cat": s.name.split(".")[0],
            "pid": pid, "tid": tid_of(s.tid),
            "ts": (s.t0 - base) * 1e6, "dur": s.dur * 1e6,
            "args": {k: _jsonable(v) for k, v in s.args.items()},
        })
    for i in instants or []:
        events.append({
            "name": i.name, "ph": "i", "cat": i.name.split(".")[0],
            "s": "t", "pid": pid, "tid": tid_of(i.tid),
            "ts": (i.t0 - base) * 1e6,
            "args": {k: _jsonable(v) for k, v in i.args.items()},
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


# ---------------------------------------------------------------------------
# Module-global tracer
# ---------------------------------------------------------------------------

NULL_TRACER = NullTracer()
_tracer: Any = NULL_TRACER

ENV_VAR = "REPRO_TRACE"


def get_tracer():
    """The active tracer — :data:`NULL_TRACER` unless tracing was
    enabled. Callers on hot paths should hold the result once per call
    site and guard bulk work on ``.enabled``."""
    return _tracer


def enable_tracing(*, jax_annotations: bool = False,
                   max_records: int = 1_000_000) -> Tracer:
    """Install (and return) a fresh recording tracer as the global."""
    global _tracer
    _tracer = Tracer(jax_annotations=jax_annotations,
                     max_records=max_records)
    return _tracer


def disable_tracing() -> None:
    global _tracer
    _tracer = NULL_TRACER


if os.environ.get(ENV_VAR):  # opt-in via environment for any entry point
    enable_tracing()


class clock_span:
    """Always-timed span: ``.dur`` is measured with ``perf_counter``
    whether or not tracing is enabled, and the span is recorded to the
    active tracer only when it is. This is what replaces the serving
    loops' ad-hoc ``t0 = perf_counter(); ...; now += perf_counter()-t0``
    pairs: the clock and the trace read the same interval."""

    __slots__ = ("name", "args", "t0", "dur", "_ctx")

    def __init__(self, name: str, **args):
        self.name = name
        self.args = args
        self.t0 = 0.0
        self.dur = 0.0
        self._ctx: Optional[_SpanCtx] = None

    def __enter__(self) -> "clock_span":
        tr = _tracer
        if tr.enabled:
            self._ctx = tr.span(self.name, **self.args)
            self._ctx.__enter__()
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self.dur = time.perf_counter() - self.t0
        if self._ctx is not None:
            self._ctx.__exit__(*exc)
            self._ctx = None
