"""Reconcile the Eq.-3 modeled clock against measured trace spans.

The offload engine charges a *modeled* serial clock (Eq. 3:
``t_compute + t_transfer``) and a modeled *overlapped* clock (layer
``l``'s compute hides layer ``l+1``'s fetches). Both were, until now,
unchecked assertions. Given the spans an instrumented engine run
recorded, this module:

1. buckets measured time into the model's own categories — *fetch*
   (demand + prefetch host->device staging) and *compute* (attention/
   router, grouped expert matmuls, spillover, embed/logits/non-MoE
   blocks) — per MoE layer;
2. measures the *actual* fetch/compute overlap per layer (wall-clock
   intersection of layer ``l`` compute spans with layer ``l+1`` fetch
   spans — the exact quantity the overlapped clock models);
3. calibrates an effective hardware profile from the run (achieved
   flops/s and link bytes/s) and rebuilds the Eq.-3 serial clock at
   measured rates;
4. checks the invariant: the rebuilt Eq.-3 serial clock explains the
   engine's measured step wall to within a stated tolerance (the
   residual is unmodeled overhead: cache accounting, dispatch, Python).

The per-layer table shows modeled (under the *configured* profile,
e.g. TPU v5e constants), calibrated (measured rates), and measured
seconds side by side, absolute and as ratios, so "where does Eq. 3
disagree with reality" is one table read.

Span-name contract (what the engine instrumentation emits):

======================  =====================================  ========
name                    meaning                                category
======================  =====================================  ========
``engine.prefill``      one whole prefill step                 step
``engine.decode_step``  one whole decode step                  step
``engine.prefetch``     one whole proactive-prefetch pass      step
``moe.pre``             attention + router for a MoE layer     compute
``moe.compute``         grouped expert compute (or fused        compute
                        compute(l) + pre(l+1))
``moe.spillover``       overflow-bucket expert compute         compute
``engine.embed``        token embedding                        compute
``engine.logits``       lm-head logits + argmax                compute
``engine.block``        a non-MoE block                        compute
``moe.fetch``           demand expert staging + upload         fetch
``moe.prefetch``        proactive expert staging + upload      fetch
``moe.account``         host-side cache accounting             overhead
======================  =====================================  ========

MoE spans carry ``layer=<moe_idx>``; compute spans without a layer are
pooled into the "other" row (the model splits step flops uniformly over
MoE layers, so "other" has no modeled column).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from .trace import SpanRecord

FETCH_SPANS = frozenset({"moe.fetch", "moe.prefetch"})
COMPUTE_SPANS = frozenset({
    "moe.pre", "moe.compute", "moe.spillover",
    "engine.embed", "engine.logits", "engine.block",
})
OVERHEAD_SPANS = frozenset({"moe.account"})
STEP_SPANS = frozenset({"engine.prefill", "engine.decode_step",
                        "engine.prefetch"})

OTHER = -1  # pseudo-layer for compute not attributable to a MoE layer


def _intersect(a: List[Tuple[float, float]],
               b: List[Tuple[float, float]]) -> float:
    """Total overlap seconds between two interval lists (merge sweep)."""
    a = sorted(a)
    b = sorted(b)
    i = j = 0
    total = 0.0
    while i < len(a) and j < len(b):
        lo = max(a[i][0], b[j][0])
        hi = min(a[i][1], b[j][1])
        if hi > lo:
            total += hi - lo
        if a[i][1] <= b[j][1]:
            i += 1
        else:
            j += 1
    return total


@dataclass
class LayerReconciliation:
    layer: int  # OTHER == unattributed compute
    transfers: int = 0
    transfer_bytes: int = 0
    measured_fetch_s: float = 0.0
    measured_compute_s: float = 0.0
    measured_overlap_s: float = 0.0  # compute(l) ∩ fetch(l+1), wall clock
    modeled_fetch_s: float = 0.0  # under the configured hw profile
    modeled_compute_s: float = 0.0
    calibrated_fetch_s: float = 0.0  # under measured effective rates
    calibrated_compute_s: float = 0.0

    @property
    def fetch_ratio(self) -> float:
        """measured / calibrated fetch (1.0 == layer behaves like the
        run-average link rate)."""
        return (self.measured_fetch_s / self.calibrated_fetch_s
                if self.calibrated_fetch_s > 0 else 0.0)

    @property
    def compute_ratio(self) -> float:
        return (self.measured_compute_s / self.calibrated_compute_s
                if self.calibrated_compute_s > 0 else 0.0)


@dataclass
class ReconciliationReport:
    hw_name: str
    tolerance: float
    # measured, from spans
    measured_serial_s: float  # Σ step spans: the engine runs serially
    measured_fetch_s: float
    measured_compute_s: float
    measured_account_s: float
    measured_overlap_s: float  # Σ per-layer compute(l) ∩ fetch(l+1)
    unmodeled_s: float  # step wall - (fetch + compute + host_time)
    # modeled, under the configured profile (prefetch included in serial
    # so it compares like-for-like with the measured fetch spans)
    modeled_serial_s: float
    modeled_overlapped_s: float
    modeled_hidden_s: float  # serial - overlapped: what Eq. 3 claims hides
    host_time_s: float
    # Eq. 3 rebuilt at measured rates — the checked invariant
    eq3_at_measured_rates_s: float
    serial_agreement_ratio: float  # eq3_at_measured_rates / measured_serial
    ok: bool
    effective_flops: float  # achieved flop/s over measured compute
    effective_link_bw: float  # achieved bytes/s over measured fetch
    layers: List[LayerReconciliation] = field(default_factory=list)

    # ------------------------------------------------------------------
    def to_json(self) -> dict:
        d = {k: v for k, v in self.__dict__.items() if k != "layers"}
        d["layers"] = [
            {**l.__dict__, "fetch_ratio": l.fetch_ratio,
             "compute_ratio": l.compute_ratio}
            for l in self.layers
        ]
        return d

    def format_table(self) -> str:
        """Per-layer modeled vs measured table + reconciliation footer."""
        ms = lambda s: f"{s * 1e3:9.3f}"
        hdr = (f"{'layer':>5} {'tx':>6} {'fetch meas(ms)':>14} "
               f"{'fetch cal(ms)':>13} {'f.ratio':>7} "
               f"{'comp meas(ms)':>13} {'comp cal(ms)':>12} {'c.ratio':>7} "
               f"{'hidden meas(ms)':>15}")
        lines = [hdr, "-" * len(hdr)]
        for l in self.layers:
            name = "other" if l.layer == OTHER else str(l.layer)
            lines.append(
                f"{name:>5} {l.transfers:>6d} {ms(l.measured_fetch_s):>14} "
                f"{ms(l.calibrated_fetch_s):>13} {l.fetch_ratio:>7.2f} "
                f"{ms(l.measured_compute_s):>13} "
                f"{ms(l.calibrated_compute_s):>12} {l.compute_ratio:>7.2f} "
                f"{ms(l.measured_overlap_s):>15}")
        lines += [
            "-" * len(hdr),
            f"measured serial (step wall)      {self.measured_serial_s * 1e3:10.3f} ms",
            f"  = fetch {self.measured_fetch_s * 1e3:.3f}"
            f" + compute {self.measured_compute_s * 1e3:.3f}"
            f" + host {self.host_time_s * 1e3:.3f}"
            f" + unmodeled {self.unmodeled_s * 1e3:.3f} ms"
            f" (accounting spans: {self.measured_account_s * 1e3:.3f} ms)",
            f"Eq.3 at measured rates           "
            f"{self.eq3_at_measured_rates_s * 1e3:10.3f} ms"
            f"  agreement {self.serial_agreement_ratio:.3f}"
            f" (tolerance ±{self.tolerance:.2f}) ->"
            f" {'OK' if self.ok else 'FAIL'}",
            f"modeled serial [{self.hw_name}]     "
            f"{self.modeled_serial_s * 1e3:10.3f} ms"
            f"   overlapped {self.modeled_overlapped_s * 1e3:.3f} ms"
            f"   (claims {self.modeled_hidden_s * 1e3:.3f} ms hidden;"
            f" measured overlap {self.measured_overlap_s * 1e3:.3f} ms)",
            f"effective rates: {self.effective_flops / 1e9:.2f} GFLOP/s, "
            f"{self.effective_link_bw / 1e9:.3f} GB/s link",
        ]
        return "\n".join(lines)


def reconcile(spans: Sequence[SpanRecord], metrics, hw, *,
              tolerance: float = 0.35) -> ReconciliationReport:
    """Check the Eq.-3 clocks of ``metrics`` (an ``EngineMetrics``)
    against the spans of the same run.

    ``ok`` asserts that the Eq.-3 serial decomposition, evaluated at the
    run's *measured* rates (achieved flops/s and link bytes/s), explains
    the measured step wall within ``tolerance`` — i.e. the model's two
    terms account for where the time actually went, with only a bounded
    unmodeled residual (cache accounting, dispatch, Python glue).
    """
    fetch_iv: Dict[int, List[Tuple[float, float]]] = {}
    comp_iv: Dict[int, List[Tuple[float, float]]] = {}
    fetch_s: Dict[int, float] = {}
    comp_s: Dict[int, float] = {}
    account_s = 0.0
    step_wall = 0.0
    for s in spans:
        layer = s.args.get("layer", OTHER)
        if s.name in FETCH_SPANS:
            fetch_s[layer] = fetch_s.get(layer, 0.0) + s.dur
            fetch_iv.setdefault(layer, []).append((s.t0, s.t1))
        elif s.name in COMPUTE_SPANS:
            comp_s[layer] = comp_s.get(layer, 0.0) + s.dur
            comp_iv.setdefault(layer, []).append((s.t0, s.t1))
        elif s.name in OVERHEAD_SPANS:
            account_s += s.dur
        elif s.name in STEP_SPANS:
            step_wall += s.dur

    layer_tx = dict(getattr(metrics, "layer_tx", {}))
    layer_tx_bytes = dict(getattr(metrics, "layer_tx_bytes", {}))
    for l, n in getattr(metrics, "layer_prefetch_tx", {}).items():
        layer_tx[l] = layer_tx.get(l, 0) + n
    for l, b in getattr(metrics, "layer_prefetch_bytes", {}).items():
        layer_tx_bytes[l] = layer_tx_bytes.get(l, 0) + b

    moe_layers = sorted(
        set(layer_tx) | {l for l in (set(fetch_s) | set(comp_s)) if l != OTHER}
    )
    L = max(len(moe_layers), 1)

    meas_fetch = sum(fetch_s.values())
    meas_comp = sum(comp_s.values())
    host_time = float(getattr(metrics, "host_time", 0.0))

    # -- calibration: effective rates achieved over this run -------------
    total_bytes = (metrics.transfer_bytes + metrics.prefetch_bytes)
    eff_flops = metrics.compute_flops / meas_comp if meas_comp > 0 else 0.0
    eff_bw = total_bytes / meas_fetch if meas_fetch > 0 else 0.0

    # -- modeled, configured profile (prefetch folded into serial) -------
    speed = hw.peak_flops * hw.mfu
    modeled_comp = metrics.compute_flops / speed
    modeled_fetch = (
        total_bytes / hw.host_link_bw
        + (metrics.transfers + metrics.prefetch_transfers)
        * hw.transfer_latency
    )
    # Injected fault delay is charged serially by both engine clocks
    # (EngineMetrics.modeled_time and the per-step overlapped spans), so
    # it belongs on the serial side here too — else overlapped > serial.
    fault_delay = float(getattr(metrics, "fault_delay_s", 0.0))
    modeled_serial = modeled_comp + modeled_fetch + host_time + fault_delay
    prefetch_t = (
        metrics.prefetch_bytes / hw.host_link_bw
        + metrics.prefetch_transfers * hw.transfer_latency
    )
    modeled_overlapped = metrics.modeled_time_overlapped(hw) + prefetch_t

    # -- per-layer rows ---------------------------------------------------
    rows: List[LayerReconciliation] = []
    for l in moe_layers:
        nxt = l + 1
        row = LayerReconciliation(
            layer=l,
            transfers=int(layer_tx.get(l, 0)),
            transfer_bytes=int(layer_tx_bytes.get(l, 0)),
            measured_fetch_s=fetch_s.get(l, 0.0),
            measured_compute_s=comp_s.get(l, 0.0),
            measured_overlap_s=_intersect(comp_iv.get(l, []),
                                          fetch_iv.get(nxt, [])),
            modeled_fetch_s=(
                layer_tx_bytes.get(l, 0) / hw.host_link_bw
                + layer_tx.get(l, 0) * hw.transfer_latency
            ),
            modeled_compute_s=modeled_comp / L,
            calibrated_fetch_s=(layer_tx_bytes.get(l, 0) / eff_bw
                                if eff_bw > 0 else 0.0),
            calibrated_compute_s=(metrics.compute_flops / L / eff_flops
                                  if eff_flops > 0 else 0.0),
        )
        rows.append(row)
    if OTHER in comp_s or OTHER in fetch_s:
        rows.append(LayerReconciliation(
            layer=OTHER,
            measured_fetch_s=fetch_s.get(OTHER, 0.0),
            measured_compute_s=comp_s.get(OTHER, 0.0),
        ))

    # -- the checked invariant -------------------------------------------
    eq3_measured = meas_fetch + meas_comp + host_time
    measured_serial = step_wall if step_wall > 0 else eq3_measured
    ratio = eq3_measured / measured_serial if measured_serial > 0 else 0.0
    ok = measured_serial > 0 and abs(1.0 - ratio) <= tolerance

    return ReconciliationReport(
        hw_name=getattr(hw, "name", "hw"),
        tolerance=tolerance,
        measured_serial_s=measured_serial,
        measured_fetch_s=meas_fetch,
        measured_compute_s=meas_comp,
        measured_account_s=account_s,
        measured_overlap_s=sum(r.measured_overlap_s for r in rows),
        unmodeled_s=max(measured_serial - eq3_measured, 0.0),
        modeled_serial_s=modeled_serial,
        modeled_overlapped_s=modeled_overlapped,
        modeled_hidden_s=max(modeled_serial - modeled_overlapped, 0.0),
        host_time_s=host_time,
        eq3_at_measured_rates_s=eq3_measured,
        serial_agreement_ratio=ratio,
        ok=ok,
        effective_flops=eff_flops,
        effective_link_bw=eff_bw,
        layers=rows,
    )
