"""Always-resident "little" experts: low-rank distillates of every
offloaded expert (MoBiLE-style big/little tier — ROADMAP item 4).

When the big expert is unavailable — its host->device fetch failed past
the retry budget, it lost the capacity race, or the request is under
deadline pressure — the engine substitutes a rank-``r`` SVD truncation
of the *effective* expert weights (base projection + the layer's folded
LoRA delta, so a fine-tuned model degrades toward its fine-tuned
behavior, not the base model's). One little bank per MoE layer lives on
the device permanently; at rank 8 it is ~``r * (d + f) / (d * f)`` of a
full expert per projection, small enough that the bank never competes
with the real resident slab for capacity.

Optionally the left factors (the large ones, ``(din, r)``) are stored
HQQ-INT4 (``quantized=True``) and dequantized per use — the bank's
footprint then approaches INT4-low-rank while the combine math is
unchanged.

The combine semantics match ``OffloadedMoEEngine._per_expert_contrib``
exactly: gate-massed fp32 accumulation per substituted expert, so a
degraded step differs from the exact step only by the low-rank weight
approximation.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from ..models.common import silu
from .quant import dequantize, quantize

_PROJS = ("wg", "wu", "wd")


class LittleExpertBank:
    """Per-MoE-layer stacked low-rank factors for every expert.

    ``host_arrays``: per-layer dicts of stacked fp weights
    ``{wg/wu/wd: (E, din, dout)}`` (the engine's host mirror).
    ``lora``: optional per-layer LoRA trees (``{"wu": {"a", "b"}, ...}``
    with leaves ``(E, din, r)`` / ``(E, r, dout)``) folded into the
    distillate at build time.
    """

    def __init__(self, host_arrays: List[Dict[str, np.ndarray]], *,
                 rank: int = 8, lora: Optional[List] = None,
                 lora_scale: float = 1.0, quantized: bool = False,
                 quant_group: int = 32):
        self.rank = rank
        self.quantized = quantized
        self.n_layers = len(host_arrays)
        self.substitutions = 0  # expert-substitution events served
        # per layer: {proj: (left (E, din, r) | QTensor of its transpose,
        #                    right (E, r, dout))}
        self.factors: List[Dict[str, tuple]] = []
        self.device_bytes = 0
        for moe_idx, arrs in enumerate(host_arrays):
            ll = lora[moe_idx] if lora is not None else None
            layer = {}
            for k in _PROJS:
                w = np.asarray(arrs[k], np.float32)  # (E, din, dout)
                if ll is not None and k in ll:
                    a = np.asarray(ll[k]["a"], np.float32)
                    b = np.asarray(ll[k]["b"], np.float32)
                    w = w + lora_scale * np.einsum("edr,erf->edf", a, b)
                u, s, vt = np.linalg.svd(w, full_matrices=False)
                r = min(rank, s.shape[-1])
                left = u[..., :r] * s[..., None, :r]  # (E, din, r)
                right = vt[..., :r, :]  # (E, r, dout)
                if quantized:
                    # groups along the contraction axis din (must divide
                    # quant_group, as for the main INT4 resident path);
                    # the tiny (r, dout) right factors stay fp32
                    ql = quantize(jnp.asarray(np.swapaxes(left, -1, -2)),
                                  group=quant_group, iters=4)
                    lstore = ql  # codes of left.T: (E, r, din)
                    self.device_bytes += (ql.packed.size
                                          + 4 * ql.scale.size
                                          + 4 * ql.zero.size)
                else:
                    lstore = jnp.asarray(left)
                    self.device_bytes += lstore.nbytes
                rstore = jnp.asarray(right)
                self.device_bytes += rstore.nbytes
                layer[k] = (lstore, rstore)
            self.factors.append(layer)

    def bytes_per_layer(self) -> int:
        return self.device_bytes // max(self.n_layers, 1)

    def _left(self, moe_idx: int, k: str):
        lstore, _ = self.factors[moe_idx][k]
        if self.quantized:
            return jnp.swapaxes(dequantize(lstore, jnp.float32), -1, -2)
        return lstore

    def expert_weights(self, moe_idx: int, e: int) -> Dict[str, jnp.ndarray]:
        """Reconstructed (din, dout) low-rank weights of one expert —
        the test/debug view of what a substitution computes with."""
        out = {}
        for k in _PROJS:
            left = self._left(moe_idx, k)[e]
            right = self.factors[moe_idx][k][1][e]
            out[k] = left @ right
        return out

    def contrib(self, moe_idx: int, h2f, gates, eids,
                expert_ids: Sequence[int], *, lora=None, lora_scale=1.0):
        """Gate-massed fp32 contribution of the little experts for
        ``expert_ids`` — the degraded-mode replacement for the big
        experts' grouped/overflow compute. ``lora`` is accepted for
        signature parity with the eager path but ignored: the bank
        already folded the LoRA delta at build time."""
        del lora, lora_scale
        facs = self.factors[moe_idx]
        lg_all = self._left(moe_idx, "wg")
        lu_all = self._left(moe_idx, "wu")
        ld_all = self._left(moe_idx, "wd")
        h = h2f.astype(jnp.float32)
        out = jnp.zeros_like(h)
        for e in expert_ids:
            hg = (h @ lg_all[e]) @ facs["wg"][1][e]
            hu = (h @ lu_all[e]) @ facs["wu"][1][e]
            h_act = silu(hg) * hu
            ye = (h_act @ ld_all[e]) @ facs["wd"][1][e]
            gate_mass = jnp.where(eids == e, gates, 0.0).sum(-1)  # (N,)
            out = out + gate_mass[:, None] * ye
            self.substitutions += 1
        return out
