"""Rank-matching loss L_rm (paper Sec 3.1.1, App C.2).

    m^(t) = sum_{i,j} I{p_b,i > p_b,j} [rho - (p_f,i - p_f,j)]_+

Upper-bounds rho * Inv(p_f, p_b) (Lemma C.8), i.e. minimizing it
maximizes a lower bound on the Kendall rank correlation with the base
router. O(E^2) per token — evaluated in token chunks to bound memory.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def rank_match_token(pb: jax.Array, pf: jax.Array, rho: float) -> jax.Array:
    """pb, pf (..., E) -> m (...,): pairwise hinge count (Eq. 12)."""
    ind = (pb[..., :, None] > pb[..., None, :]).astype(jnp.float32)
    diff = pf[..., :, None] - pf[..., None, :]
    hinge = jnp.maximum(rho - diff, 0.0)
    return (ind * hinge).sum((-1, -2))


def inversion_count(pb: jax.Array, pf: jax.Array) -> jax.Array:
    """Kendall inversion count Inv(pf, pb) per token (Def C.7)."""
    ind_b = pb[..., :, None] > pb[..., None, :]
    ind_f = pf[..., :, None] < pf[..., None, :]
    return (ind_b & ind_f).sum((-1, -2))


def rank_match_loss(pb: jax.Array, pf: jax.Array, *, rho: float,
                    token_chunk: int = 128) -> jax.Array:
    """pb, pf (B, T, E) -> scalar mean over (B, T) of m^(t) (one layer)."""
    B, T, E = pf.shape
    pb = lax.stop_gradient(pb.astype(jnp.float32))
    pf = pf.astype(jnp.float32)
    tc = min(token_chunk, T)
    nt = -(-T // tc)
    pad = nt * tc - T
    if pad:
        # padded tokens contribute 0: make pb constant there (no i>j pairs)
        pb = jnp.pad(pb, ((0, 0), (0, pad), (0, 0)))
        pf = jnp.pad(pf, ((0, 0), (0, pad), (0, 0)))
    pb_c = pb.reshape(B, nt, tc, E).transpose(1, 0, 2, 3)
    pf_c = pf.reshape(B, nt, tc, E).transpose(1, 0, 2, 3)

    def body(acc, xs):
        pb_i, pf_i = xs
        return acc + rank_match_token(pb_i, pf_i, rho).sum(), None

    total, _ = lax.scan(body, jnp.zeros((), jnp.float32), (pb_c, pf_c))
    return total / (B * T)
