"""Offloaded MoE inference engine (paper Sec 3.2, Eq. 3).

TPU adaptation of the paper's VRAM/DRAM split (DESIGN.md Sec 2):

  * resident pool  — per-layer expert cache in accelerator memory
                     (optionally HQQ-INT4 quantized, Sec 3.2 / D.5)
  * offload pool   — host memory (``pinned_host`` on real TPU; numpy here)
  * miss           — host->device DMA, counted and costed by Eq. 3

The engine iterates blocks in Python (per-layer control is the point:
the cache manager must interpose *between* the router and the expert
computation), reusing the exact block functions of the model substrate,
so its outputs match ``model.decode_step`` bit-for-bit when the cache is
large enough. Intended for the reproduction-scale models; production
decode uses the fused ``serve_step``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models.blocks import apply_block_decode, apply_block_full, init_block_cache
from ..models.common import rms_norm
from ..models.mlp import apply_mlp
from ..models.model import compute_logits, embed_tokens
from ..models.moe import router_probs, top_k_route
from ..models.runtime import Runtime
from ..models.common import silu
from .expert_cache import ModelExpertCache
from .quant import (QTensor, dequantize_linear, matmul_layout, qmatmul,
                    quant_bytes, quantize_linear)


# ---------------------------------------------------------------------------
# Hardware profile (v5e target; see DESIGN.md Sec 2 for constants)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class HardwareProfile:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12  # bf16
    hbm_bw: float = 819e9
    host_link_bw: float = 32e9  # host<->device DMA (PCIe-gen4-like)
    transfer_latency: float = 30e-6  # per-transfer fixed cost
    host_flops: float = 2e12  # host-side expert execution (Fiddler mode)
    mfu: float = 0.4  # assumed compute efficiency for Eq. 3


PCIE5_H100 = HardwareProfile(
    name="h100-pcie5", peak_flops=989e12, hbm_bw=3350e9, host_link_bw=64e9
)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


@dataclass
class EngineMetrics:
    decode_tokens: int = 0
    transfers: int = 0
    transfer_bytes: int = 0
    prefetch_transfers: int = 0
    prefetch_bytes: int = 0
    host_executed: int = 0
    compute_flops: float = 0.0
    wall_time: float = 0.0

    def modeled_time(self, hw: HardwareProfile) -> float:
        """Eq. 3: Time_decode ~ Time_compute + N_miss * Time_transfer."""
        t_compute = self.compute_flops / (hw.peak_flops * hw.mfu)
        t_transfer = (
            self.transfer_bytes / hw.host_link_bw
            + self.transfers * hw.transfer_latency
        )
        t_host = self.host_executed_time(hw)
        return t_compute + t_transfer + t_host

    def host_executed_time(self, hw) -> float:
        return getattr(self, "_host_time", 0.0)

    def throughput(self, hw: HardwareProfile, batch: int = 1) -> float:
        t = self.modeled_time(hw)
        return (self.decode_tokens * batch) / max(t, 1e-12)


class OffloadedMoEEngine:
    """Greedy decoding with a per-layer offloaded expert cache."""

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        capacity: int,
        policy: str = "lfu",
        gamma: float = 0.9,
        quantized: bool = False,
        quant_group: int = 32,
        hw: HardwareProfile = HardwareProfile(),
        cpu_execute: bool = False,
        stream_all: bool = False,
        lora=None,
        lora_scale: float = 1.0,
        kernel_backend: str = "ref",
    ):
        assert cfg.has_router, "offload engine needs an MoE architecture"
        self.cfg = cfg
        self.rt = Runtime(zero_drop=True, kernel_backend=kernel_backend)
        self.kernel_backend = kernel_backend
        self.hw = hw
        self.capacity = capacity
        self.quantized = quantized
        self.quant_group = quant_group
        self.cpu_execute = cpu_execute
        self.stream_all = stream_all
        self.lora = lora
        self.lora_scale = lora_scale

        # ---- unstack the scanned groups into a flat per-layer list -----
        self.layers: List[dict] = []  # {"name", "spec", "params", "moe_idx"}
        self.moe_layer_ids: List[int] = []
        for gi, g in enumerate(cfg.layout):
            gparams = params["groups"][f"g{gi}"]
            glora = (lora or {}).get(f"g{gi}", {})
            for r in range(g.repeats):
                for pi, bname in enumerate(g.pattern):
                    b = cfg.block_defs[bname]
                    if b.kind == "shared_attn":
                        lp = params["shared"]
                        ll = None
                    else:
                        lp = jax.tree.map(lambda a: a[r], gparams[f"p{pi}"])
                        ll = (
                            jax.tree.map(lambda a: a[r], glora[f"p{pi}"])
                            if f"p{pi}" in glora
                            else None
                        )
                    entry = {"name": bname, "spec": b, "params": lp, "lora": ll}
                    if b.moe is not None:
                        entry["moe_idx"] = len(self.moe_layer_ids)
                        self.moe_layer_ids.append(len(self.layers))
                    self.layers.append(entry)

        self.params_top = {
            k: v for k, v in params.items() if k in ("embed", "lm_head", "final_norm")
        }
        self.moe_spec = cfg.moe_spec
        E = self.moe_spec.num_experts

        # ---- split expert weights: host store + resident buffers -------
        self.host_store: List[Dict[int, dict]] = []  # per moe layer: eid -> weights
        self.resident: List[Dict[int, dict]] = []  # per moe layer: eid -> device weights
        self.expert_bytes_fp = 0
        self.expert_bytes_q = 0
        for li in self.moe_layer_ids:
            ffn = self.layers[li]["params"]["ffn"]
            store = {}
            for e in range(E):
                w = {
                    "wg": np.asarray(ffn["wg"][e]),
                    "wu": np.asarray(ffn["wu"][e]),
                    "wd": np.asarray(ffn["wd"][e]),
                }
                if quantized:
                    # groups along the contraction axis (quantize_linear)
                    # so misses can run the fused dequant-matmul kernel
                    wq = {k: quantize_linear(jnp.asarray(v), group=quant_group,
                                             iters=4)
                          for k, v in w.items()}
                    store[e] = {"q": jax.tree.map(np.asarray, wq,
                                                  is_leaf=lambda x: isinstance(x, jax.Array))}
                    if e == 0 and li == self.moe_layer_ids[0]:
                        self.expert_bytes_q = sum(quant_bytes(q) for q in wq.values())
                else:
                    store[e] = w
                if e == 0 and li == self.moe_layer_ids[0]:
                    self.expert_bytes_fp = sum(v.nbytes for v in w.values())
            self.host_store.append(store)
            self.resident.append({})
            # remove expert weights from the per-layer device params (keep
            # router + shared expert, which are always resident)
            keep = {k: v for k, v in ffn.items() if k in ("router", "shared")}
            self.layers[li]["params"] = {**self.layers[li]["params"], "ffn": keep}

        self.expert_bytes = self.expert_bytes_q if quantized else self.expert_bytes_fp
        self.cache = ModelExpertCache(
            len(self.moe_layer_ids), E, capacity, policy=policy, gamma=gamma
        )
        self.metrics = EngineMetrics()
        self._flops_per_token = cfg.param_counts()["active"] * 2  # fwd only

    # ------------------------------------------------------------------
    def _device_weights(self, store: dict) -> dict:
        """Move one expert's host weights onto the device. Under a Pallas
        backend quantized experts stay INT4 (the compute runs the fused
        dequant matmul); under "ref" they dequantize ONCE here so the
        per-token matmuls don't repeat full-weight dequant work."""
        if self.quantized:
            qt = {k: QTensor(*[jnp.asarray(x) if isinstance(x, np.ndarray) else x
                               for x in v]) for k, v in store["q"].items()}
            if self.rt.kernel_choice("int4_matmul").use_pallas:
                return {k: matmul_layout(v) for k, v in qt.items()}
            return {k: dequantize_linear(v, jnp.float32) for k, v in qt.items()}
        return {k: jnp.asarray(v) for k, v in store.items()}

    def _fetch(self, moe_idx: int, eid: int, *, prefetch: bool = False):
        """Host -> device transfer of one expert (simulated DMA)."""
        store = self.host_store[moe_idx][eid]
        w = self._device_weights(store)
        nbytes = self.expert_bytes_q if self.quantized else self.expert_bytes_fp
        self.resident[moe_idx][eid] = w
        if prefetch:
            self.metrics.prefetch_transfers += 1
            self.metrics.prefetch_bytes += nbytes
        else:
            self.metrics.transfers += 1
            self.metrics.transfer_bytes += nbytes
        # enforce the device budget: drop non-cached residents
        cached = self.cache.layers[moe_idx].resident
        for stale in [e for e in self.resident[moe_idx] if e not in cached and e != eid]:
            del self.resident[moe_idx][stale]

    def prefetch(self, scores: np.ndarray):
        """Predictor-driven proactive cache load (Sec 3.2). scores (L, E)."""
        self.cache.prefill_from_scores(scores)
        for moe_idx, cache in enumerate(self.cache.layers):
            for e in cache.resident:
                if e not in self.resident[moe_idx]:
                    self._fetch(moe_idx, e, prefetch=True)

    # ------------------------------------------------------------------
    def _moe_forward(self, moe_idx: int, layer: dict, h2):
        """h2 (B, T, d) -> (B, T, d) expert output under the cache."""
        b = layer["spec"]
        spec = b.moe
        B, T, dm = h2.shape
        h2f = h2.reshape(B * T, dm)
        probs = router_probs(layer["params"]["ffn"], h2f, spec)
        gates, eids = top_k_route(probs, spec.top_k)
        eids_np = np.asarray(eids)

        # --- cache accounting: token-sequential accesses ---------------
        host_set = set()
        for n in range(B * T):
            if self.stream_all:
                self.metrics.transfers += spec.top_k
                self.metrics.transfer_bytes += spec.top_k * self.expert_bytes
            else:
                missed = self.cache.access(moe_idx, eids_np[n])
                for e in missed:
                    if self.cpu_execute:
                        # Fiddler mode: run the expert on the host instead
                        # of transferring (cost model; see baselines)
                        self.metrics.transfers -= 0  # no DMA
                        self.metrics.host_executed += 1
                        host_set.add(int(e))
                    else:
                        self._fetch(moe_idx, int(e))

        # --- actual computation (exact, using whatever weights) --------
        needed = set(int(e) for e in np.unique(eids_np))
        full = layer["lora"]
        out = jnp.zeros_like(h2f, dtype=jnp.float32)

        def mm(x, w):  # fused dequant matmul for INT4-resident experts
            if isinstance(w, jax.Array) or isinstance(w, np.ndarray):
                return x @ w
            return qmatmul(x, w, backend=self.kernel_backend)

        for e in sorted(needed):
            w = self.resident[moe_idx].get(e)
            if w is None:  # cpu_execute / stream_all paths still need weights
                w = self._device_weights(self.host_store[moe_idx][e])
            hg, hu = mm(h2f, w["wg"]), mm(h2f, w["wu"])
            if full is not None:  # LoRA rides as a separate low-rank term
                sc = self.lora_scale
                hu = hu + sc * ((h2f @ full["wu"]["a"][e]) @ full["wu"]["b"][e]).astype(hu.dtype)
            h_act = silu(hg) * hu
            ye = mm(h_act, w["wd"])
            if full is not None:
                sc = self.lora_scale
                ye = ye + sc * ((h_act @ full["wd"]["a"][e]) @ full["wd"]["b"][e]).astype(ye.dtype)
            gate_mass = jnp.where(eids == e, gates, 0.0).sum(-1)  # (N,)
            out = out + gate_mass[:, None] * ye.astype(jnp.float32)

        y = out.astype(h2.dtype)
        if spec.shared_d_ff:
            y = y + apply_mlp(layer["params"]["ffn"]["shared"], h2f)
        return y.reshape(B, T, dm), probs.reshape(B, T, -1)

    # ------------------------------------------------------------------
    def _block_forward(self, layer: dict, x, positions, caches, idx, decode_pos=None):
        """One block, full-seq (decode_pos None) or single-step."""
        cfg, b = self.cfg, layer["spec"]
        p = layer["params"]
        if b.kind == "mamba":
            if decode_pos is None:
                x2, aux = apply_block_full(p, cfg, b, x, positions, self.rt,
                                           want_cache=True, cache_slots=0)
                caches[idx] = aux["kv"]
                return x2
            from ..models.mamba2 import apply_mamba_decode

            h = rms_norm(p["ln1"], x, cfg.norm_eps)
            y, caches[idx] = apply_mamba_decode(p["mixer"], h, caches[idx], b.ssm)
            return x + y

        # attention part
        from ..models.attention import attend_full, cache_from_prefill, decode_attend

        h = rms_norm(p["ln1"], x, cfg.norm_eps)
        if decode_pos is None:
            y, (k, v) = attend_full(p["mixer"], b.attn, h, positions, b.attn.window,
                                    return_kv=True, rt=self.rt)
            caches[idx] = cache_from_prefill(k, v, b.attn, self._n_slots)
        else:
            y, caches[idx] = decode_attend(p["mixer"], b.attn, h, caches[idx],
                                           decode_pos, b.attn.window)
        x = x + y
        h2 = rms_norm(p["ln2"], x, cfg.norm_eps)
        if b.moe is not None:
            y2, _ = self._moe_forward(layer["moe_idx"], layer, h2)
        else:
            y2 = apply_mlp(p["ffn"], h2)
        return x + y2

    # ------------------------------------------------------------------
    def generate(self, prompt_tokens, max_new_tokens: int,
                 prefix_embed=None) -> dict:
        """Greedy decoding. prompt_tokens (B, T) int32. Returns dict with
        tokens, metrics, throughput (Eq. 3 model)."""
        t0 = time.perf_counter()
        cfg = self.cfg
        toks = jnp.asarray(prompt_tokens)
        B, T = toks.shape
        self._n_slots = T + max_new_tokens + (prefix_embed.shape[1] if prefix_embed is not None else 0)

        # prefill
        x = embed_tokens(self.params_top, cfg, toks, prefix_embed)
        Tt = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(Tt), (B, Tt))
        caches: List[Any] = [None] * len(self.layers)
        for idx, layer in enumerate(self.layers):
            x = self._block_forward(layer, x, positions, caches, idx)
        logits = compute_logits(self.params_top, cfg, x, self.rt)
        self.metrics.compute_flops += self._flops_per_token * B * Tt
        next_tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)

        out_tokens = [next_tok]
        pos = jnp.asarray(Tt, jnp.int32)
        for _ in range(max_new_tokens - 1):
            x = embed_tokens(self.params_top, cfg, next_tok)
            for idx, layer in enumerate(self.layers):
                x = self._block_forward(layer, x, positions, caches, idx, decode_pos=pos)
            logits = compute_logits(self.params_top, cfg, x, self.rt)
            next_tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
            out_tokens.append(next_tok)
            pos = pos + 1
            self.metrics.decode_tokens += 1
            self.metrics.compute_flops += self._flops_per_token * B
        self.metrics.decode_tokens += 1
        self.metrics.wall_time = time.perf_counter() - t0

        m = self.metrics
        m._host_time = (
            m.host_executed * (3 * 2 * cfg.d_model * self.moe_spec.d_ff) / self.hw.host_flops
        )
        return {
            "tokens": jnp.concatenate(out_tokens, axis=1),
            "metrics": m,
            "cache_stats": self.cache.stats(),
            "transfers_per_layer": self.cache.transfers_per_layer(),
            "throughput_tok_s": m.throughput(self.hw, batch=B),
        }
