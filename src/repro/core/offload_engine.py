"""Offloaded MoE inference engine (paper Sec 3.2, Eq. 3).

TPU adaptation of the paper's VRAM/DRAM split (DESIGN.md Sec 2):

  * resident pool  — per-layer expert cache in accelerator memory
                     (optionally HQQ-INT4 quantized, Sec 3.2 / D.5)
  * offload pool   — host memory (``pinned_host`` on real TPU; numpy here)
  * miss           — host->device DMA, counted and costed by Eq. 3

The engine iterates blocks in Python (per-layer control is the point:
the cache manager must interpose *between* the router and the expert
computation) and its outputs match ``model.decode_step`` bit-for-bit
when the cache is large enough.

Two implementations share the cache/metrics substrate:

``impl="slab"`` (default) — the hot path. Residents live in per-layer
*slabs*: stacked device buffers ``(C, d, f)`` (fp32, or the INT4
``matmul_layout`` triplet under a Pallas backend) updated in place via
a donated ``.at[slot].set`` so a fetch never reallocates or retraces.
Each MoE layer runs two jitted calls: attention + router (one trace per
block kind), then — after the vectorized host-side cache accounting
(``LayerExpertCache.access_batch``) syncs the slab — one grouped
``moe_gmm`` over all experts at once (tokens sorted into per-slot
buffers; LoRA rides as a batched low-rank term).

``impl="dict"`` — the pre-rewrite engine: per-expert dict-of-arrays
residents, per-token Python cache accounting, eager per-expert matmuls.
Kept as the reproduction-scale baseline ``benchmarks/offload_bench.py``
measures the slab engine against.

Beyond the serial Eq. 3 clock, :class:`EngineMetrics` records per-step,
per-MoE-layer transfer events, from which an *overlapped* clock models
cross-layer prefetch hiding: layer ``l``'s router output issues layer
``l+1``'s fetches, so a step costs
``t_tx[0] + sum_l max(t_compute_l, t_tx[l+1])`` (FloE-style pipeline;
always <= the serial clock).
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import BlockSpec, ModelConfig
from ..models.blocks import apply_block_full
from ..models.common import rms_norm, silu
from ..models.mlp import apply_mlp
from ..models.model import compute_logits, embed_tokens
from ..models.moe import (Dispatch, combine_tokens, dispatch_tokens,
                          router_probs, top_k_route)
from ..models.runtime import Runtime
from ..obs.trace import get_tracer
from ..faults import FetchPolicy, get_fault_plan
from .expert_cache import ModelExpertCache
from .little_expert import LittleExpertBank
from .quant import (QTensor, dequantize_linear, matmul_layout, qmatmul,
                    quant_bytes, quantize_linear)


def _obs_sync(x):
    """Fence async dispatch at span boundaries when tracing, so spans
    measure the work they wrap instead of whatever the scheduler
    happened to drain later; a no-op (async preserved) otherwise."""
    if get_tracer().enabled:
        jax.block_until_ready(x)
    return x

def _quiet_donation(fn):
    """Slab updates donate the old buffer; CPU backends fall back to
    copying and warn — the donation is still correct (and free on TPU).
    Suppress that one warning around OUR donated calls only, instead of
    mutating the process-global warning filters at import time."""
    def wrapped(*args, **kwargs):
        with warnings.catch_warnings():
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable")
            return fn(*args, **kwargs)
    return wrapped


# ---------------------------------------------------------------------------
# Hardware profile (v5e target; see DESIGN.md Sec 2 for constants)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class HardwareProfile:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12  # bf16
    hbm_bw: float = 819e9
    host_link_bw: float = 32e9  # host<->device DMA (PCIe-gen4-like)
    transfer_latency: float = 30e-6  # per-transfer fixed cost
    host_flops: float = 2e12  # host-side expert execution (Fiddler mode)
    mfu: float = 0.4  # assumed compute efficiency for Eq. 3


PCIE5_H100 = HardwareProfile(
    name="h100-pcie5", peak_flops=989e12, hbm_bw=3350e9, host_link_bw=64e9
)


# ---------------------------------------------------------------------------
# Metrics: serial Eq. 3 clock + overlapped prefetch clock
# ---------------------------------------------------------------------------


@dataclass
class EngineMetrics:
    decode_tokens: int = 0
    transfers: int = 0
    transfer_bytes: int = 0
    prefetch_transfers: int = 0
    prefetch_bytes: int = 0
    host_executed: int = 0
    compute_flops: float = 0.0
    wall_time: float = 0.0
    prefill_wall_time: float = 0.0  # host seconds spent in prefill steps
    host_time: float = 0.0  # modeled host-side expert execution (set in generate)
    # resilience accounting (PR 8): modeled seconds lost to injected
    # transfer spikes, failed fetch attempts and retry backoff; counts of
    # retries, failed attempts and little-expert substitutions
    fault_delay_s: float = 0.0
    fetch_retries: int = 0
    fetch_failures: int = 0
    degraded_uses: int = 0
    # per engine step (prefill counts as one, then one per decode step):
    # total flops and per-MoE-layer demand-transfer counts/bytes — the
    # event records behind the overlapped clock — plus that step's
    # injected fault delay (charged serially on both clocks: a stalled
    # retry blocks the wave either way)
    step_flops: List[float] = field(default_factory=list)
    step_tx: List[np.ndarray] = field(default_factory=list)
    step_tx_bytes: List[np.ndarray] = field(default_factory=list)
    step_fault_delay: List[float] = field(default_factory=list)
    # overlapped-clock seconds of records dropped via drop_step_records
    # (keeps modeled_time_overlapped cumulative after trimming)
    overlapped_dropped: float = 0.0
    # cumulative per-MoE-layer transfer totals (moe_idx -> count/bytes).
    # Unlike the per-step event records these survive drop_step_records,
    # so obs.reconcile can build its per-layer table for long-lived
    # engines (the wave server drops records per request)
    layer_tx: Dict[int, int] = field(default_factory=dict)
    layer_tx_bytes: Dict[int, int] = field(default_factory=dict)
    layer_prefetch_tx: Dict[int, int] = field(default_factory=dict)
    layer_prefetch_bytes: Dict[int, int] = field(default_factory=dict)

    # -- recording ---------------------------------------------------------
    def begin_step(self, n_moe_layers: int) -> None:
        self.step_flops.append(0.0)
        self.step_tx.append(np.zeros(n_moe_layers, np.int64))
        self.step_tx_bytes.append(np.zeros(n_moe_layers, np.int64))
        self.step_fault_delay.append(0.0)

    def add_fault_delay(self, seconds: float) -> None:
        self.fault_delay_s += seconds
        if self.step_fault_delay:
            self.step_fault_delay[-1] += seconds

    def add_flops(self, flops: float) -> None:
        self.compute_flops += flops
        if self.step_flops:
            self.step_flops[-1] += flops

    def add_demand_transfers(self, moe_idx: int, n: int, nbytes: int) -> None:
        self.transfers += n
        self.transfer_bytes += nbytes
        self.layer_tx[moe_idx] = self.layer_tx.get(moe_idx, 0) + n
        self.layer_tx_bytes[moe_idx] = (
            self.layer_tx_bytes.get(moe_idx, 0) + nbytes)
        if self.step_tx:
            self.step_tx[-1][moe_idx] += n
            self.step_tx_bytes[-1][moe_idx] += nbytes

    def add_prefetch_transfers(self, moe_idx: int, n: int, nbytes: int) -> None:
        """Proactive (predictor-driven) transfers: real link traffic, but
        charged outside the demand clocks — tracked per layer for the
        reconciliation table."""
        self.prefetch_transfers += n
        self.prefetch_bytes += nbytes
        self.layer_prefetch_tx[moe_idx] = (
            self.layer_prefetch_tx.get(moe_idx, 0) + n)
        self.layer_prefetch_bytes[moe_idx] = (
            self.layer_prefetch_bytes.get(moe_idx, 0) + nbytes)

    def drop_step_records(self, hw: HardwareProfile) -> None:
        """Discard the per-step event records so long-lived engines (the
        wave server) don't retain one array pair per decode step. The
        records' overlapped seconds are folded into
        ``overlapped_dropped`` first, so :meth:`modeled_time_overlapped`
        stays cumulative — exact as long as the same ``hw`` is used
        throughout, which the engine's own ``self.hw`` guarantees."""
        self.overlapped_dropped += self.overlapped_span(hw)
        self.step_flops.clear()
        self.step_tx.clear()
        self.step_tx_bytes.clear()
        self.step_fault_delay.clear()

    # -- clocks ------------------------------------------------------------
    def modeled_time(self, hw: HardwareProfile) -> float:
        """Eq. 3, serial: Time_decode ~ Time_compute + N_miss * Time_transfer."""
        t_compute = self.compute_flops / (hw.peak_flops * hw.mfu)
        t_transfer = (
            self.transfer_bytes / hw.host_link_bw
            + self.transfers * hw.transfer_latency
        )
        return t_compute + t_transfer + self.host_time + self.fault_delay_s

    def serial_span(self, hw: HardwareProfile, start_step: int = 0,
                    end_step: Optional[int] = None) -> float:
        """Serial Eq.-3 seconds of steps[start_step:end_step] only (no
        host time): per-step flops + every demand transfer. The
        per-request time-to-first-token is the serial span of just the
        prefill step."""
        speed = hw.peak_flops * hw.mfu
        total = 0.0
        for flops, tx, txb, fd in zip(self.step_flops[start_step:end_step],
                                      self.step_tx[start_step:end_step],
                                      self.step_tx_bytes[start_step:end_step],
                                      self.step_fault_delay[start_step:end_step]):
            total += flops / speed
            total += float(txb.sum()) / hw.host_link_bw
            total += float(tx.sum()) * hw.transfer_latency
            total += fd
        return total

    def overlapped_span(self, hw: HardwareProfile, start_step: int = 0,
                        end_step: Optional[int] = None) -> float:
        """Overlapped-clock seconds of steps[start_step:end_step] only
        (no host time) — lets callers accumulate deltas instead of
        re-walking the whole history per request."""
        speed = hw.peak_flops * hw.mfu
        total = 0.0
        for flops, tx, txb, fd in zip(self.step_flops[start_step:end_step],
                                      self.step_tx[start_step:end_step],
                                      self.step_tx_bytes[start_step:end_step],
                                      self.step_fault_delay[start_step:end_step]):
            total += fd  # retry stalls serialize: nothing hides them
            L = len(tx)
            if L == 0:
                total += flops / speed
                continue
            t_tx = txb / hw.host_link_bw + tx * hw.transfer_latency
            seg = flops / speed / L
            t = float(t_tx[0])  # the first layer's fetches hide nothing
            for l in range(L):
                t += max(seg, float(t_tx[l + 1]) if l + 1 < L else 0.0)
            total += t
        return total

    def modeled_time_overlapped(self, hw: HardwareProfile) -> float:
        """Eq. 3 with cross-layer prefetch hiding: layer ``l``'s router
        output issues layer ``l+1``'s fetches, so a step's transfers
        overlap the previous layer's compute —
        ``t_step = t_tx[0] + sum_l max(t_compute_l, t_tx[l+1])``
        with the step's compute split uniformly over its MoE layers.
        Always <= :meth:`modeled_time` (``max(a, b) <= a + b``)."""
        if not self.step_flops and not self.overlapped_dropped:
            return self.modeled_time(hw)
        return self.overlapped_dropped + self.overlapped_span(hw) + self.host_time

    def throughput(self, hw: HardwareProfile, batch: int = 1,
                   overlap: bool = False) -> float:
        t = self.modeled_time_overlapped(hw) if overlap else self.modeled_time(hw)
        return (self.decode_tokens * batch) / max(t, 1e-12)

    # -- durable state (recovery checkpoints) ------------------------------
    _STATE_SCALARS = (
        "decode_tokens", "transfers", "transfer_bytes", "prefetch_transfers",
        "prefetch_bytes", "host_executed", "compute_flops", "wall_time",
        "prefill_wall_time", "host_time", "fault_delay_s", "fetch_retries",
        "fetch_failures", "degraded_uses", "overlapped_dropped",
    )
    _STATE_LAYER_DICTS = (
        "layer_tx", "layer_tx_bytes", "layer_prefetch_tx",
        "layer_prefetch_bytes",
    )

    def state(self) -> dict:
        """Cumulative counters as a plain dict (per-step event records
        are transient and deliberately excluded — a restored engine
        starts with a clean step history). Layer-dict keys become
        strings so the snapshot survives msgpack strict-key decoding."""
        out = {k: getattr(self, k) for k in self._STATE_SCALARS}
        for k in self._STATE_LAYER_DICTS:
            out[k] = {str(i): v for i, v in getattr(self, k).items()}
        return out

    def load_state(self, state: dict) -> None:
        for k in self._STATE_SCALARS:
            if k in state:
                setattr(self, k, state[k])
        for k in self._STATE_LAYER_DICTS:
            if k in state:
                setattr(self, k, {int(i): v for i, v in state[k].items()})

    # -- obs ---------------------------------------------------------------
    def publish(self, registry=None, **labels) -> None:
        """Publish the scalar counters onto a metrics registry (the
        global one by default) as labeled gauges. Purely additive — the
        existing dict/attribute contracts are untouched."""
        from ..obs.registry import REGISTRY

        reg = registry if registry is not None else REGISTRY
        g = lambda name, v: reg.gauge("engine_" + name, **labels).set(v)
        g("decode_tokens", self.decode_tokens)
        g("transfers", self.transfers)
        g("transfer_bytes", self.transfer_bytes)
        g("prefetch_transfers", self.prefetch_transfers)
        g("prefetch_bytes", self.prefetch_bytes)
        g("host_executed", self.host_executed)
        g("compute_flops", self.compute_flops)
        g("wall_time_s", self.wall_time)
        g("prefill_wall_time_s", self.prefill_wall_time)
        g("host_time_s", self.host_time)
        g("fault_delay_s", self.fault_delay_s)
        g("fetch_retries", self.fetch_retries)
        g("fetch_failures", self.fetch_failures)
        g("degraded_uses", self.degraded_uses)


def _pad_bucket(n: int) -> int:
    """Smallest power of two >= n — pads variable expert counts to a
    handful of shapes so the batched-fetch / overflow jits stay cached."""
    return 1 << (max(n, 1) - 1).bit_length()


# ---------------------------------------------------------------------------
# Resident slab: stacked per-layer expert buffers with a slot free-list
# ---------------------------------------------------------------------------


class ExpertSlab:
    """Stacked device-resident expert weights for ONE MoE layer.

    ``buffers`` is a pytree whose leaves all carry a leading slot axis of
    size ``C`` (fp: ``wg/wu/wd (C, d, f)``; INT4 ``matmul_layout``:
    packed/scale/zero triplets). Slots are recycled through a free-list
    and overwritten in place by a donated ``.at[slot].set`` — residency
    changes never reallocate the slab or retrace the compute."""

    def __init__(self, num_experts: int, capacity: int, buffers):
        self.E = num_experts
        self.C = capacity
        self.buffers = buffers
        self.residents: set = set()
        self.free: List[int] = list(range(capacity - 1, -1, -1))
        # expert id -> slot (C == "absent" sentinel; also the dispatch
        # drop index), and slot -> expert id (for slot-keyed LoRA gather)
        self.slot_of_expert = np.full(num_experts, capacity, np.int32)
        self.slot_expert = np.zeros(max(capacity, 1), np.int32)
        self.last_use: Dict[int, int] = {}  # physical LRU over compute use
        self.tick = 0
        self._dev: Optional[tuple] = None  # cached device copies of the maps
        # compact-variant index uploads keyed by (active experts, their
        # slots) — keys encode the current assignment, so entries never
        # go stale when slots are recycled
        self._compact_maps: Dict[tuple, tuple] = {}

    def drop(self, e: int) -> None:
        slot = int(self.slot_of_expert[e])
        self.slot_of_expert[e] = self.C
        self.free.append(slot)
        self.residents.discard(e)
        self.last_use.pop(e, None)
        self._dev = None

    def claim(self, e: int) -> int:
        """Assign a free slot to expert ``e`` (bookkeeping only — the
        caller writes the buffers, possibly for many slots at once)."""
        slot = self.free.pop()
        self.slot_of_expert[e] = slot
        self.slot_expert[slot] = e
        self.residents.add(e)
        self._dev = None
        return slot

    def device_maps(self) -> tuple:
        """(slot_of_expert (E,), slot_expert (C,)) as device arrays,
        re-uploaded only after residency changes."""
        if self._dev is None:
            self._dev = (jnp.asarray(self.slot_of_expert),
                         jnp.asarray(self.slot_expert))
        return self._dev


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


class OffloadedMoEEngine:
    """Greedy decoding with a per-layer offloaded expert cache."""

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        capacity: int,
        policy: str = "lfu",
        gamma: float = 0.9,
        quantized: bool = False,
        quant_group: int = 32,
        hw: HardwareProfile = HardwareProfile(),
        cpu_execute: bool = False,
        stream_all: bool = False,
        lora=None,
        lora_scale: float = 1.0,
        kernel_backend: str = "ref",
        impl: str = "slab",
        little_experts: bool = False,
        little_rank: int = 8,
        little_quantized: bool = False,
        fetch_policy: Optional[FetchPolicy] = None,
        pressure_frac: float = 0.75,
    ):
        assert cfg.has_router, "offload engine needs an MoE architecture"
        assert impl in ("slab", "dict"), impl
        self.cfg = cfg
        self.rt = Runtime(zero_drop=True, kernel_backend=kernel_backend)
        self.kernel_backend = kernel_backend
        self.hw = hw
        self.capacity = capacity
        self.quantized = quantized
        self.quant_group = quant_group
        self.cpu_execute = cpu_execute
        self.stream_all = stream_all
        self.lora = lora
        self.lora_scale = lora_scale
        self.impl = impl
        self.fetch_policy = fetch_policy or FetchPolicy()
        # deadline pressure: once a request has burned this fraction of
        # its Eq.-3 budget, remaining misses go all-little (quality 0)
        self.pressure_frac = pressure_frac
        self._step_quality = 1.0  # effective per-step quality dial
        self._gen_step = 0

        # ---- unstack the scanned groups into a flat per-layer list -----
        self.layers: List[dict] = []  # {"name", "spec", "params", "moe_idx"}
        self.moe_layer_ids: List[int] = []
        for gi, g in enumerate(cfg.layout):
            gparams = params["groups"][f"g{gi}"]
            glora = (lora or {}).get(f"g{gi}", {})
            for r in range(g.repeats):
                for pi, bname in enumerate(g.pattern):
                    b = cfg.block_defs[bname]
                    if b.kind == "shared_attn":
                        lp = params["shared"]
                        ll = None
                    else:
                        lp = jax.tree.map(lambda a: a[r], gparams[f"p{pi}"])
                        ll = (
                            jax.tree.map(lambda a: a[r], glora[f"p{pi}"])
                            if f"p{pi}" in glora
                            else None
                        )
                    entry = {"name": bname, "spec": b, "params": lp, "lora": ll}
                    if b.moe is not None:
                        entry["moe_idx"] = len(self.moe_layer_ids)
                        self.moe_layer_ids.append(len(self.layers))
                    self.layers.append(entry)

        self.params_top = {
            k: v for k, v in params.items() if k in ("embed", "lm_head", "final_norm")
        }
        self.moe_spec = cfg.moe_spec
        E = self.moe_spec.num_experts

        # ---- split expert weights: host store + resident buffers -------
        self.host_store: List[Dict[int, dict]] = []  # per moe layer: eid -> weights
        self.host_arrays: List[Dict[str, np.ndarray]] = []  # stacked (E, ...) fp
        self.resident: List[Dict[int, dict]] = []  # dict impl: eid -> device weights
        self.expert_bytes_fp = 0
        self.expert_bytes_q = 0
        for li in self.moe_layer_ids:
            ffn = self.layers[li]["params"]["ffn"]
            # contiguous stacked host copy: per-expert entries are views,
            # and the slab engine's batched fetch gathers rows directly
            arrs = {k: np.asarray(ffn[k]) for k in ("wg", "wu", "wd")}
            self.host_arrays.append(arrs)
            store = {}
            for e in range(E):
                w = {k: arrs[k][e] for k in ("wg", "wu", "wd")}
                if quantized:
                    # groups along the contraction axis (quantize_linear)
                    # so misses can run the fused dequant-matmul kernel
                    wq = {k: quantize_linear(jnp.asarray(v), group=quant_group,
                                             iters=4)
                          for k, v in w.items()}
                    store[e] = {"q": jax.tree.map(np.asarray, wq,
                                                  is_leaf=lambda x: isinstance(x, jax.Array))}
                    if e == 0 and li == self.moe_layer_ids[0]:
                        self.expert_bytes_q = sum(quant_bytes(q) for q in wq.values())
                else:
                    store[e] = w
                if e == 0 and li == self.moe_layer_ids[0]:
                    self.expert_bytes_fp = sum(v.nbytes for v in w.values())
            self.host_store.append(store)
            self.resident.append({})
            # remove expert weights from the per-layer device params (keep
            # router + shared expert, which are always resident)
            keep = {k: v for k, v in ffn.items() if k in ("router", "shared")}
            self.layers[li]["params"] = {**self.layers[li]["params"], "ffn": keep}

        self.expert_bytes = self.expert_bytes_q if quantized else self.expert_bytes_fp
        self.cache = ModelExpertCache(
            len(self.moe_layer_ids), E, capacity, policy=policy, gamma=gamma
        )
        self.metrics = EngineMetrics()
        self._flops_per_token = cfg.param_counts()["active"] * 2  # fwd only

        # always-resident low-rank distillates: the degraded-mode tier
        # substituted on fetch failure, capacity miss, or deadline
        # pressure (one extra little slab per MoE layer; LoRA deltas are
        # folded in at build time so compute never re-applies them)
        self.little: Optional[LittleExpertBank] = None
        if little_experts:
            self.little = LittleExpertBank(
                self.host_arrays, rank=little_rank,
                lora=[self.layers[li]["lora"] for li in self.moe_layer_ids],
                lora_scale=lora_scale, quantized=little_quantized,
                quant_group=quant_group)

        self._quant_pallas = (
            quantized and self.rt.kernel_choice("int4_matmul").use_pallas
        )
        if impl == "slab":
            self._init_slabs()
            self._jit_cache: Dict[tuple, Any] = {}
            self._embed_fn = jax.jit(
                lambda p, t, pe=None: embed_tokens(p, cfg, t, pe))
            self._next_tok_fn = jax.jit(
                lambda p, x: jnp.argmax(
                    compute_logits(p, cfg, x, self.rt)[:, -1:], -1
                ).astype(jnp.int32))
        else:
            self._embed_fn = lambda p, t, pe=None: embed_tokens(p, cfg, t, pe)
            self._next_tok_fn = lambda p, x: jnp.argmax(
                compute_logits(p, cfg, x, self.rt)[:, -1:], -1
            ).astype(jnp.int32)

    # ------------------------------------------------------------------
    # shared host-store -> device-weight materialization
    # ------------------------------------------------------------------
    def _device_weights(self, store: dict) -> dict:
        """Move one expert's host weights onto the device. Under a Pallas
        backend quantized experts stay INT4 (the compute runs the fused
        dequant matmul); under "ref" they dequantize ONCE here so the
        per-token matmuls don't repeat full-weight dequant work."""
        if self.quantized:
            qt = {k: QTensor(*[jnp.asarray(x) if isinstance(x, np.ndarray) else x
                               for x in v]) for k, v in store["q"].items()}
            if self._quant_pallas:
                return {k: matmul_layout(v) for k, v in qt.items()}
            return {k: dequantize_linear(v, jnp.float32) for k, v in qt.items()}
        return {k: jnp.asarray(v) for k, v in store.items()}

    def _slab_leaves(self, w: dict) -> dict:
        """Device weights -> the slab's per-expert leaf structure."""
        if self._quant_pallas:
            return {k: {"packed": v.packed, "scale": v.scale, "zero": v.zero}
                    for k, v in w.items()}
        return w

    # ------------------------------------------------------------------
    # slab impl
    # ------------------------------------------------------------------
    def _init_slabs(self):
        E, C = self.moe_spec.num_experts, self.capacity
        tmpl = self._slab_leaves(self._device_weights(self.host_store[0][0]))
        # fresh buffers per layer: the donating update consumes its input,
        # so slabs must never alias each other's device arrays
        self._slabs = [
            ExpertSlab(E, C, jax.tree.map(
                lambda a: jnp.zeros((C,) + a.shape, a.dtype), tmpl))
            for _ in self.moe_layer_ids
        ]
        # one trace serves every layer and every slot: the slab buffers are
        # donated so the update happens in place (no reallocation)
        self._slab_set = _quiet_donation(jax.jit(
            lambda bufs, w, slot: jax.tree.map(
                lambda s, x: s.at[slot].set(x), bufs, w),
            donate_argnums=(0,),
        ))
        # batched variant: K experts land in one host->device transfer and
        # one donated scatter (slot padding = C, dropped). jit re-traces
        # per bucket size, and bucket sizes are powers of two, so the
        # trace count stays O(log E)
        self._slab_scatter = _quiet_donation(jax.jit(
            lambda bufs, ws, slots: jax.tree.map(
                lambda s, w: s.at[slots].set(w, mode="drop"), bufs, ws),
            donate_argnums=(0,),
        ))

    def _stack_host(self, moe_idx: int, eids: List[int], bucket: int) -> dict:
        """Stack fp host weights for ``eids`` into (bucket, ...) arrays —
        one DMA's worth of contiguous expert rows. Padding repeats the
        first expert (finite values, one gather, no zero-fill): padded
        scatter slots are dropped, and padded overflow groups receive
        zero token rows, so the pad content never reaches an output."""
        idx = np.full(bucket, eids[0], np.int64)
        idx[: len(eids)] = eids
        return {k: a[idx] for k, a in self.host_arrays[moe_idx].items()}

    def _sync_slab(self, moe_idx: int) -> int:
        """Mirror the cache manager's resident set into the device slab."""
        slab = self._slabs[moe_idx]
        target = self.cache.layers[moe_idx].resident
        for e in [e for e in slab.residents if e not in target]:
            slab.drop(e)
        new = sorted(target - slab.residents)
        if not new:
            return 0
        if self.quantized:  # per-expert: leaves differ per projection
            for e in new:
                leaves = self._slab_leaves(
                    self._device_weights(self.host_store[moe_idx][e]))
                slab.buffers = self._slab_set(slab.buffers, leaves,
                                              slab.claim(e))
            return len(new)
        bucket = _pad_bucket(len(new))
        ws = self._stack_host(moe_idx, new, bucket)
        slots = np.full(bucket, slab.C, np.int32)
        for i, e in enumerate(new):
            slots[i] = slab.claim(e)
        slab.buffers = self._slab_scatter(slab.buffers, ws,
                                          jnp.asarray(slots))
        return len(new)

    def _ensure_resident(self, moe_idx: int, needed: List[int]):
        """Physically load as many of ``needed`` as fit into the slab.

        The *modeled* residency/transfer accounting is entirely the cache
        manager's (``access_batch`` above); the slab is the physical pool
        of C device slots behind it, and between steps it may retain any
        C experts. Retaining by recency of *compute use* minimizes real
        host->device traffic: the token-sequential accounting can stream
        more experts through its C logical slots than survive a batch,
        and mirroring that churn would re-fetch weights the slab already
        holds. Returns (missing, update): the experts that still did not
        fit (served by the overflow bucket), and the pending slab load —
        stacked host rows + target slots — which the NEXT compute call
        applies in-jit so a fetch costs no extra launch. Slot
        bookkeeping is committed here; only the buffer write is
        deferred."""
        slab = self._slabs[moe_idx]
        slab.tick += 1
        if slab.residents.issuperset(needed):  # warm fast path
            for e in needed:
                slab.last_use[e] = slab.tick
            return [], None
        needed_set = set(needed)
        new = [e for e in needed if e not in slab.residents]
        update = None
        if new:
            evictable = sorted(
                (e for e in slab.residents if e not in needed_set),
                key=lambda e: slab.last_use.get(e, -1))
            load = new[: len(slab.free) + len(evictable)]
            while len(slab.free) < len(load):
                slab.drop(evictable.pop(0))
            if load:
                bucket = _pad_bucket(len(load))
                ws = self._stack_host(moe_idx, load, bucket)
                slots = np.full(bucket, slab.C, np.int32)
                for i, e in enumerate(load):
                    slots[i] = slab.claim(e)
                update = (ws, jnp.asarray(slots))
        for e in needed:
            if e in slab.residents:
                slab.last_use[e] = slab.tick
        return [e for e in needed if e not in slab.residents], update

    def _pre_decode_body(self, b: BlockSpec, p, x, cache, pos):
        from ..models.attention import decode_attend

        cfg = self.cfg
        h = rms_norm(p["ln1"], x, cfg.norm_eps)
        y, new_cache = decode_attend(p["mixer"], b.attn, h, cache, pos,
                                     b.attn.window)
        xa = x + y
        h2 = rms_norm(p["ln2"], xa, cfg.norm_eps)
        B, T, dm = h2.shape
        h2f = h2.reshape(B * T, dm)
        probs = router_probs(p["ffn"], h2f, b.moe)
        gates, eids = top_k_route(probs, b.moe.top_k)
        return xa, h2f, gates, eids, new_cache

    def _jit_pre_decode(self, b: BlockSpec):
        return jax.jit(partial(self._pre_decode_body, b))

    def _jit_pre_full(self, b: BlockSpec):
        cfg, rt = self.cfg, self.rt

        def fn(p, x, positions, n_slots):
            from ..models.attention import attend_full, cache_from_prefill

            h = rms_norm(p["ln1"], x, cfg.norm_eps)
            y, (k, v) = attend_full(p["mixer"], b.attn, h, positions,
                                    b.attn.window, return_kv=True, rt=rt)
            kv = cache_from_prefill(k, v, b.attn, n_slots)
            xa = x + y
            h2 = rms_norm(p["ln2"], xa, cfg.norm_eps)
            B, T, dm = h2.shape
            h2f = h2.reshape(B * T, dm)
            probs = router_probs(p["ffn"], h2f, b.moe)
            gates, eids = top_k_route(probs, b.moe.top_k)
            return xa, h2f, gates, eids, kv

        return jax.jit(fn, static_argnames=("n_slots",))

    def _dequant_slab_mat(self, leaves: dict) -> jax.Array:
        """INT4 matmul_layout slab (packed (C, K//2, N)) -> fp32 (C, K, N):
        the kernel oracle's dequant, vmapped over the slot axis — one
        source of truth for the packing."""
        from ..kernels.int4_matmul.ref import dequant_ref

        return jax.vmap(lambda p, s, z: dequant_ref(p, s, z, self.quant_group))(
            leaves["packed"], leaves["scale"], leaves["zero"])

    def _group_core(self, dequant: bool):
        """The grouped compute shared by the resident-slab step and the
        overflow step: sort the token top-k assignments by slot, run ONE
        grouped matmul per projection over all slots at once, add LoRA
        as a slot-gathered batched low-rank term, gate-combine."""
        sc = self.lora_scale
        choice = self.rt.kernel_choice("moe_gmm")

        def low_rank(x, a, b_, out_dtype):
            t = jnp.einsum("cnd,cdr->cnr", x.astype(jnp.float32),
                           a.astype(jnp.float32))
            return (sc * jnp.einsum("cnr,crf->cnf", t,
                                    b_.astype(jnp.float32))).astype(out_dtype)

        def core(slabs, lora, soe, slot_expert, h2f, gates, eids):
            C = slot_expert.shape[0]
            N, K = eids.shape
            slots = soe[eids]  # (N, K); == C where the expert is absent
            flat = slots.reshape(N * K)
            oh = jax.nn.one_hot(flat, C + 1, dtype=jnp.int32)
            sizes = oh.sum(0)[:C]  # tokens per slot (ragged gmm groups)
            if N == 1:
                # single-token step (the wave server's shape): every
                # active slot's buffer row IS the token — no sort/scatter
                buf = jnp.broadcast_to(h2f[None], (C, 1, h2f.shape[-1]))
                buf = buf * (sizes > 0)[:, None, None].astype(buf.dtype)
                d = None
            else:
                pos = (jnp.cumsum(oh, axis=0) * oh).sum(-1) - 1
                keep = (flat < C).reshape(N, K)
                d = Dispatch(
                    eids=slots,
                    pos=jnp.where(keep, pos.reshape(N, K), 0),
                    gates=jnp.where(keep, gates, 0.0),
                    cap=N,
                )
                buf = dispatch_tokens(d, h2f, C)  # (C, N, d) slot-sorted
            if dequant:
                wg, wu, wd = (self._dequant_slab_mat(slabs[k])
                              for k in ("wg", "wu", "wd"))
            else:
                wg, wu, wd = slabs["wg"], slabs["wu"], slabs["wd"]
            if choice.use_pallas:
                from ..kernels.moe_gmm import ops as gmm_ops

                mm = partial(gmm_ops.gmm, backend="pallas",
                             interpret=choice.interpret, group_sizes=sizes)
            else:
                mm = lambda a, w: jnp.einsum("cnd,cdf->cnf", a, w)
            hg = mm(buf, wg)
            hu = mm(buf, wu)
            if lora is not None:
                au = lora["wu"]["a"][slot_expert]
                bu = lora["wu"]["b"][slot_expert]
                hu = hu + low_rank(buf, au, bu, hu.dtype)
            h_act = silu(hg) * hu
            yb = mm(h_act, wd)
            if lora is not None:
                ad = lora["wd"]["a"][slot_expert]
                bd = lora["wd"]["b"][slot_expert]
                yb = yb + low_rank(h_act, ad, bd, yb.dtype)
            if N == 1:  # gate-combine by direct slot gather
                safe = jnp.minimum(flat, C - 1)
                g1 = jnp.where(flat < C, gates[0], 0.0)
                gathered = yb[safe, 0]  # (K, d)
                return jnp.einsum(
                    "kd,k->d", gathered.astype(jnp.float32), g1
                )[None].astype(yb.dtype)
            return combine_tokens(d, yb)  # (N, d)

        return core

    @staticmethod
    def _apply_slab_update(slabs, update):
        """Apply a deferred fetch (stacked rows + slots; pad slots == C
        are dropped) to the slab buffers, inside the compute jit."""
        if update is None:
            return slabs
        ws, slots = update
        return jax.tree.map(lambda s, w: s.at[slots].set(w, mode="drop"),
                            slabs, ws)

    def _jit_moe_apply(self, b: BlockSpec):
        """Resident-slab per-MoE-layer step (+ the shared expert).
        Applies the layer's pending slab load first (donated buffers, so
        in place), then computes. Assignments whose expert is not in the
        slab (within-batch capacity overflow, degenerate C < K,
        cpu/stream modes) are dropped here and served by the overflow
        step. Returns (y, updated slab buffers)."""
        spec = b.moe
        core = self._group_core(self._quant_pallas)

        def fn(ffn, lora, slabs, update, soe, slot_expert, h2f, gates, eids):
            slabs = self._apply_slab_update(slabs, update)
            y = core(slabs, lora, soe, slot_expert, h2f, gates, eids)
            if spec.shared_d_ff:
                y = y + apply_mlp(ffn["shared"], h2f)
            return y, slabs

        return _quiet_donation(jax.jit(fn, donate_argnums=(2,)))

    def _jit_moe_overflow(self, b: BlockSpec):
        """Grouped compute over an ephemeral stacked bucket of experts
        the slab could not hold this step (fp weights, no shared)."""
        core = self._group_core(False)

        def fn(lora, ws, soe, slot_expert, h2f, gates, eids):
            return core(ws, lora, soe, slot_expert, h2f, gates, eids)

        return jax.jit(fn)

    def _jit_moe_compact(self, b: BlockSpec):
        """Like the resident-slab step, but over a gathered bucket of the
        ACTIVE slots only. The reference grouped matmul cannot skip empty
        groups the way the ragged Pallas kernel does, so when this step
        touches far fewer experts than the slab holds (small decode
        batches, large C), gathering G slots and computing (G, N, ...)
        beats streaming all C slots' weights through the einsum."""
        spec = b.moe
        core = self._group_core(self._quant_pallas)

        def fn(ffn, lora, slabs, update, group_slots, soe_g, group_expert,
               h2f, gates, eids):
            slabs = self._apply_slab_update(slabs, update)
            w = jax.tree.map(lambda s: s[group_slots], slabs)
            y = core(w, lora, soe_g, group_expert, h2f, gates, eids)
            if spec.shared_d_ff:
                y = y + apply_mlp(ffn["shared"], h2f)
            return y, slabs

        return _quiet_donation(jax.jit(fn, donate_argnums=(2,)))

    def _jit_fused_dec(self, b_l: BlockSpec, b_next: BlockSpec, compact: bool):
        """Layer l's grouped MoE apply + residual + layer l+1's
        attention/router in ONE jitted call — the decode hot loop runs
        one launch (and one host sync) per MoE layer instead of two."""
        spec = b_l.moe
        core = self._group_core(self._quant_pallas)

        def fn(ffn, lora, slabs, update, maps, h2f, gates, eids, xa,
               p_next, cache_next, pos):
            slabs = self._apply_slab_update(slabs, update)
            if compact:
                gs, soe_g, ge = maps
                w = jax.tree.map(lambda s: s[gs], slabs)
                y = core(w, lora, soe_g, ge, h2f, gates, eids)
            else:
                soe, se = maps
                y = core(slabs, lora, soe, se, h2f, gates, eids)
            if spec.shared_d_ff:
                y = y + apply_mlp(ffn["shared"], h2f)
            B = xa.shape[0]
            x = xa + y.reshape(B, -1, xa.shape[-1])
            return (*self._pre_decode_body(b_next, p_next, x, cache_next, pos),
                    slabs)

        return _quiet_donation(jax.jit(fn, donate_argnums=(2,)))

    def _jitted(self, kind: str, bname: str):
        key = (kind, bname)
        if key not in self._jit_cache:
            b = self.cfg.block_defs[bname]
            maker = {"pre_dec": self._jit_pre_decode,
                     "pre_full": self._jit_pre_full,
                     "moe": self._jit_moe_apply,
                     "moe_compact": self._jit_moe_compact,
                     "moe_over": self._jit_moe_overflow}[kind]
            self._jit_cache[key] = maker(b)
        return self._jit_cache[key]

    def _jitted_fused(self, bname_l: str, bname_next: str, compact: bool):
        key = ("fused_dec", bname_l, bname_next, compact)
        if key not in self._jit_cache:
            self._jit_cache[key] = self._jit_fused_dec(
                self.cfg.block_defs[bname_l], self.cfg.block_defs[bname_next],
                compact)
        return self._jit_cache[key]

    # ------------------------------------------------------------------
    # resilience: fault-injected transfer trials + the quality dial
    # ------------------------------------------------------------------
    def _resilience_active(self) -> bool:
        """One cheap guard for every hot-path hook: with no fault plan
        installed and the quality dial at 1.0, every resilience branch
        is skipped and decode is bit-for-bit the unmodified engine."""
        return get_fault_plan().enabled or (
            self.little is not None and self._step_quality < 1.0)

    def _degrade_roll(self, moe_idx: int, e: int) -> bool:
        """Deterministic per-(layer, expert, step) quality roll: True
        means substitute the little expert instead of fetching the big
        one. quality 1.0 never degrades by choice; 0.0 always does."""
        q = self._step_quality
        if q >= 1.0:
            return False
        h = (moe_idx * 0x9E3779B1 ^ e * 0x85EBCA77
             ^ self._gen_step * 0xC2B2AE3D) & 0xFFFFFFFF
        h ^= h >> 16
        h = (h * 0x45D9F3B) & 0xFFFFFFFF
        h ^= h >> 16
        return (h / 2.0**32) >= q

    def _guard_fetch(self, moe_idx: int, eids, *, prefetch: bool = False):
        """Fault-plan transfer trials for each expert in ``eids``.
        Charges modeled fault delay for latency spikes, failed attempts
        (the failed DMA burned real link time) and retry backoff;
        returns the experts whose fetch was abandoned once the retry
        budget or per-fetch deadline ran out. Demand fetches without a
        little bank cannot degrade — they retry until success (the
        no-resilience baseline the chaos bench measures), bounded only
        by the policy's hard_cap. Prefetches are always bounded
        best-effort: an abandoned prefetch just stays cold."""
        plan = get_fault_plan()
        if not plan.enabled:
            return []
        pol = self.fetch_policy
        m = self.metrics
        per_try = (self.expert_bytes / self.hw.host_link_bw
                   + self.hw.transfer_latency)
        can_degrade = prefetch or self.little is not None
        dropped = []
        for e in eids:
            spent, attempt = 0.0, 0
            while True:
                spike = plan.transfer_spike(moe_idx)
                if spike:
                    m.add_fault_delay(spike)
                if not plan.fetch_fails(moe_idx):
                    break
                m.fetch_failures += 1
                delay = per_try + pol.backoff(attempt)
                spent += delay
                m.add_fault_delay(delay)
                attempt += 1
                if can_degrade and not pol.attempts_allowed(attempt, spent):
                    dropped.append(e)
                    break
                if attempt >= pol.hard_cap:  # runaway guard only
                    break
                m.fetch_retries += 1
        return dropped

    def _degrade_misses(self, moe_idx: int, missed):
        """Resilience verdicts over one step's modeled misses: the
        quality roll first — an expert degraded by choice is never
        fetched, so it skips the fault trial and pays nothing — then
        fault trials on whatever still wants the link. Degraded experts
        leave the modeled resident set (they were never fetched, so
        future steps re-miss them honestly) and their transfers go
        uncharged. Returns (degraded_ids, n_charged)."""
        uniq = sorted(set(int(e) for e in missed))
        degraded = set()
        if self.little is not None and self._step_quality < 1.0:
            degraded = {e for e in uniq if self._degrade_roll(moe_idx, e)}
        degraded |= set(self._guard_fetch(
            moe_idx, [e for e in uniq if e not in degraded]))
        if not degraded:
            return [], len(missed)
        resident = self.cache.layers[moe_idx].resident
        for e in degraded:
            resident.discard(e)
        self.metrics.degraded_uses += len(degraded)
        n_charged = sum(1 for e in missed if int(e) not in degraded)
        return sorted(degraded), n_charged

    def _miss_verdict(self, moe_idx: int, e: int) -> bool:
        """Single-miss degrade verdict for the token-sequential dict
        path: the quality roll first (degrading by choice skips the
        fetch and its fault trial entirely), then the fault-plan
        trial."""
        if self.little is not None and self._degrade_roll(moe_idx, e):
            return True
        return bool(self._guard_fetch(moe_idx, [e]))

    def _apply_storm(self, frac: float) -> None:
        """Eviction storm: a co-tenant thrashes device memory — drop a
        ``frac`` fraction of every layer's residents (modeled AND
        physical), forcing re-misses on the next touch."""
        plan = get_fault_plan()
        for moe_idx, cache in enumerate(self.cache.layers):
            for v in plan.storm_victims(cache.resident, frac):
                cache.resident.discard(v)
                cache.evictions += 1
                if self.impl == "slab":
                    slab = self._slabs[moe_idx]
                    if v in slab.residents:
                        slab.drop(v)
                else:
                    self.resident[moe_idx].pop(v, None)

    def _guard_prefetch(self) -> None:
        """Fault trials for the pending prefetch loads (cache residents
        not yet physically present): abandoned experts are dropped from
        the modeled resident set before the physical sync, so they stay
        cold and may demand-miss later — no substitution, prefetch is
        best-effort by definition."""
        for moe_idx in range(len(self.moe_layer_ids)):
            target = self.cache.layers[moe_idx].resident
            if self.impl == "slab":
                have = self._slabs[moe_idx].residents
            else:
                have = self.resident[moe_idx].keys()
            new = sorted(e for e in target if e not in have)
            for e in self._guard_fetch(moe_idx, new, prefetch=True):
                target.discard(e)

    # ------------------------------------------------------------------
    def _fetch(self, moe_idx: int, eid: int, *, prefetch: bool = False):
        """Host -> device transfer of one expert (dict impl; simulated DMA)."""
        name = "moe.prefetch" if prefetch else "moe.fetch"
        with get_tracer().span(name, layer=moe_idx, experts=1):
            store = self.host_store[moe_idx][eid]
            w = _obs_sync(self._device_weights(store))
        nbytes = self.expert_bytes_q if self.quantized else self.expert_bytes_fp
        self.resident[moe_idx][eid] = w
        if prefetch:
            self.metrics.add_prefetch_transfers(moe_idx, 1, nbytes)
        else:
            self.metrics.add_demand_transfers(moe_idx, 1, nbytes)
        # enforce the device budget: drop non-cached residents
        cached = self.cache.layers[moe_idx].resident
        for stale in [e for e in self.resident[moe_idx] if e not in cached and e != eid]:
            del self.resident[moe_idx][stale]

    def prefetch(self, scores: np.ndarray):
        """Predictor-driven proactive cache load (Sec 3.2). scores (L, E)."""
        with get_tracer().span("engine.prefetch"):
            self.cache.prefill_from_scores(scores)
            if get_fault_plan().enabled:
                self._guard_prefetch()
            if self.impl == "slab":
                for moe_idx in range(len(self.moe_layer_ids)):
                    with get_tracer().span("moe.prefetch", layer=moe_idx):
                        added = self._sync_slab(moe_idx)
                        if added:
                            _obs_sync(self._slabs[moe_idx].buffers)
                    self.metrics.add_prefetch_transfers(
                        moe_idx, added, added * self.expert_bytes)
                return
            for moe_idx, cache in enumerate(self.cache.layers):
                for e in cache.resident:
                    if e not in self.resident[moe_idx]:
                        self._fetch(moe_idx, e, prefetch=True)

    # ------------------------------------------------------------------
    # recovery: durable cache state, warm revival, integrity audit
    # ------------------------------------------------------------------
    def cache_state(self) -> List[dict]:
        """Per-layer cache snapshots (resident set + policy scores) for
        a recovery checkpoint — the MELINOE-valuable state a cold
        restart would otherwise re-pay in transfer churn."""
        return self.cache.state()

    def revive(self, cache_state: List[dict], *, warm: bool = True) -> dict:
        """Restore a checkpointed cache and (``warm=True``) physically
        prefetch the checkpointed resident set back into the device
        slabs before serving resumes — the restart path that preserves
        the warmed expert placement instead of cold-starting.

        Returns ``{"loaded", "bytes", "modeled_s"}`` so callers can
        charge the revival DMA to their clock (the loads are counted as
        prefetch transfers, same as a predictor prefetch)."""
        self.cache.load_state(cache_state, resident=warm)
        loaded = 0
        if warm:
            with get_tracer().span("engine.revive"):
                if self.impl == "slab":
                    for moe_idx in range(len(self.moe_layer_ids)):
                        added = self._sync_slab(moe_idx)
                        if added:
                            _obs_sync(self._slabs[moe_idx].buffers)
                            self.metrics.add_prefetch_transfers(
                                moe_idx, added, added * self.expert_bytes)
                        loaded += added
                else:
                    for moe_idx, cache in enumerate(self.cache.layers):
                        for e in sorted(cache.resident):
                            if e not in self.resident[moe_idx]:
                                self._fetch(moe_idx, e, prefetch=True)
                                loaded += 1
        nbytes = loaded * self.expert_bytes
        modeled = (nbytes / self.hw.host_link_bw
                   + loaded * self.hw.transfer_latency)
        return {"loaded": loaded, "bytes": nbytes, "modeled_s": modeled}

    def resync_slabs(self) -> int:
        """Self-heal: force physical residency back in line with the
        cache manager's accounting. Drops stale physical residents (and,
        slab impl, reloads missing cached experts). Only the watchdog
        calls this, on detected drift — routine syncing would defeat the
        slab's LRU-of-compute-use retention."""
        healed = 0
        if self.impl == "slab":
            for moe_idx in range(len(self.moe_layer_ids)):
                slab = self._slabs[moe_idx]
                target = self.cache.layers[moe_idx].resident
                drift = len(set(slab.residents) - target)
                healed += drift + self._sync_slab(moe_idx)
        else:
            for moe_idx, cache in enumerate(self.cache.layers):
                res = self.resident[moe_idx]
                for e in [e for e in res if e not in cache.resident]:
                    del res[e]
                    healed += 1
        return healed

    def audit(self) -> List[tuple]:
        """Integrity check (watchdog contract): cross-checks the slab
        free-list / slot maps against the cache manager's accounting.
        Returns ``(severity, message)`` tuples — ``"hard"`` violations
        mean corrupted bookkeeping (fail fast), ``"drift"`` means
        physical residency exceeds the modeled budget (self-healable via
        :meth:`resync_slabs`). NOTE: slab residents *not* in the cache
        manager's set are normal, not drift — the slab deliberately
        retains evicted experts by compute-use LRU (see
        ``_ensure_resident``) — so only budget/bookkeeping breaks count."""
        v: List[tuple] = []
        for msg in self.cache.audit():
            v.append(("hard", f"cache: {msg}"))
        E = self.moe_spec.num_experts
        if self.impl == "slab":
            for moe_idx, slab in enumerate(self._slabs):
                pre = f"slab[L{moe_idx}]"
                if len(slab.free) + len(slab.residents) != slab.C:
                    v.append(("hard", f"{pre}: free {len(slab.free)} + "
                              f"resident {len(slab.residents)} != C {slab.C}"))
                used = []
                for e in slab.residents:
                    s = int(slab.slot_of_expert[e])
                    if not (0 <= s < slab.C):
                        v.append(("hard", f"{pre}: resident {e} has no slot"))
                    elif int(slab.slot_expert[s]) != e:
                        v.append(("hard", f"{pre}: slot map mismatch for "
                                  f"expert {e} (slot {s} claims "
                                  f"{int(slab.slot_expert[s])})"))
                    else:
                        used.append(s)
                if sorted(used + list(slab.free)) != list(range(slab.C)):
                    v.append(("hard", f"{pre}: slots not a disjoint "
                              f"partition of free + used"))
                ghosts = [e for e in range(E)
                          if int(slab.slot_of_expert[e]) != slab.C
                          and e not in slab.residents]
                if ghosts:
                    v.append(("hard", f"{pre}: non-resident experts with "
                              f"slots: {ghosts[:8]}"))
        else:
            for moe_idx, cache in enumerate(self.cache.layers):
                res = self.resident[moe_idx]
                stale = sorted(set(res) - cache.resident)
                if stale:
                    v.append(("drift", f"dict[L{moe_idx}]: physical residents "
                              f"outside the cache budget: {stale[:8]}"))
                if len(res) > self.capacity + len(stale):
                    v.append(("hard", f"dict[L{moe_idx}]: {len(res)} residents "
                              f"exceed capacity {self.capacity}"))
        return v

    # ------------------------------------------------------------------
    # dict impl MoE forward (the pre-rewrite reference path)
    # ------------------------------------------------------------------
    def _moe_forward(self, moe_idx: int, layer: dict, h2):
        """h2 (B, T, d) -> (B, T, d) expert output under the cache."""
        tr = get_tracer()
        b = layer["spec"]
        spec = b.moe
        B, T, dm = h2.shape
        h2f = h2.reshape(B * T, dm)
        with tr.span("moe.pre", layer=moe_idx):
            probs = router_probs(layer["params"]["ffn"], h2f, spec)
            gates, eids = top_k_route(probs, spec.top_k)
            eids_np = np.asarray(eids)

        # --- cache accounting: token-sequential accesses ---------------
        # the account span brackets the whole loop; demand fetches nest
        # their own moe.fetch spans inside it, so reconciliation treats
        # moe.account as informational rather than additive
        degraded: set = set()
        resilient = self._resilience_active()
        with tr.span("moe.account", layer=moe_idx, tokens=B * T):
            for n in range(B * T):
                if self.stream_all:
                    self.metrics.add_demand_transfers(
                        moe_idx, spec.top_k, spec.top_k * self.expert_bytes)
                else:
                    missed = self.cache.access(moe_idx, eids_np[n])
                    for e in missed:
                        e = int(e)
                        if self.cpu_execute:
                            # Fiddler mode: run the expert on the host instead
                            # of transferring (cost model; see baselines)
                            self.metrics.host_executed += 1
                        elif resilient and self._miss_verdict(moe_idx, e):
                            # abandoned fetch / quality roll: serve the
                            # little expert, stay modeled-non-resident
                            self.cache.layers[moe_idx].resident.discard(e)
                            if e not in degraded:
                                self.metrics.degraded_uses += 1
                            degraded.add(e)
                        else:
                            # a later successful fetch supersedes an
                            # earlier give-up for the same expert
                            degraded.discard(e)
                            self._fetch(moe_idx, e)

        # --- actual computation (exact, using whatever weights) --------
        needed = set(int(e) for e in np.unique(eids_np)) - degraded

        def weight_for(e):  # cpu_execute / stream_all paths still need weights
            w = self.resident[moe_idx].get(e)
            return w if w is not None else self._device_weights(
                self.host_store[moe_idx][e])

        with tr.span("moe.compute", layer=moe_idx, experts=len(needed)):
            out = self._per_expert_contrib(h2f, gates, eids, sorted(needed),
                                           weight_for, layer["lora"])
            if degraded:
                with tr.span("moe.degraded", layer=moe_idx,
                             experts=len(degraded)):
                    out = out + self.little.contrib(
                        moe_idx, h2f, gates, eids, sorted(degraded))
            y = out.astype(h2.dtype)
            if spec.shared_d_ff:
                y = y + apply_mlp(layer["params"]["ffn"]["shared"], h2f)
            _obs_sync(y)
        return y.reshape(B, T, dm), probs.reshape(B, T, -1)

    def _per_expert_contrib(self, h2f, gates, eids, expert_ids, weight_for,
                            lora):
        """The eager per-expert gated-MLP loop shared by the dict engine
        and the slab engine's quantized overflow path: gate-massed fp32
        accumulation over ``expert_ids``, LoRA as a separate low-rank
        term, fused dequant matmul for INT4 weights."""
        out = jnp.zeros_like(h2f, dtype=jnp.float32)

        def mm(x, w):
            if isinstance(w, jax.Array):
                return x @ w
            return qmatmul(x, w, backend=self.kernel_backend)

        for e in expert_ids:
            w = weight_for(e)
            hg, hu = mm(h2f, w["wg"]), mm(h2f, w["wu"])
            if lora is not None:
                sc = self.lora_scale
                hu = hu + sc * ((h2f @ lora["wu"]["a"][e]) @ lora["wu"]["b"][e]).astype(hu.dtype)
            h_act = silu(hg) * hu
            ye = mm(h_act, w["wd"])
            if lora is not None:
                sc = self.lora_scale
                ye = ye + sc * ((h_act @ lora["wd"]["a"][e]) @ lora["wd"]["b"][e]).astype(ye.dtype)
            gate_mass = jnp.where(eids == e, gates, 0.0).sum(-1)  # (N,)
            out = out + gate_mass[:, None] * ye.astype(jnp.float32)
        return out

    # ------------------------------------------------------------------
    # slab impl MoE forward
    # ------------------------------------------------------------------
    def _prep_moe(self, moe_idx: int, layer: dict, xa, h2f, gates, eids):
        """Host half of the per-MoE-layer step: cache accounting +
        physical residency + compute-variant choice. Returns the pending
        record :meth:`_finish_moe` (or a fused call) consumes."""
        tr = get_tracer()
        degraded: List[int] = []
        with tr.span("moe.account", layer=moe_idx):
            eids_np = np.asarray(eids)
            N, K = eids_np.shape

            # --- cache accounting: one vectorized call per layer per step
            if self.stream_all:
                self.metrics.add_demand_transfers(
                    moe_idx, N * K, N * K * self.expert_bytes)
            else:
                missed = self.cache.layers[moe_idx].access_batch(eids_np)
                if self.cpu_execute:
                    self.metrics.host_executed += len(missed)
                elif missed:
                    if self._resilience_active():
                        degraded, n_charged = self._degrade_misses(
                            moe_idx, missed)
                    else:
                        n_charged = len(missed)
                    if n_charged:
                        self.metrics.add_demand_transfers(
                            moe_idx, n_charged,
                            n_charged * self.expert_bytes)

        # --- physical residency: load what this step computes ----------
        slab = self._slabs[moe_idx]
        needed = sorted(set(eids_np.ravel().tolist()))
        if degraded:
            dset = set(degraded)
            needed = [e for e in needed if e not in dset]
            # a degraded expert must never be served from a stale
            # physical slot the slab happened to retain
            for e in degraded:
                if e in slab.residents:
                    slab.drop(e)
        update = None
        with tr.span("moe.fetch", layer=moe_idx):
            if self.cpu_execute or self.stream_all:
                # host-executed / streamed experts never persist on device:
                # everything runs through the per-step overflow bucket
                missing = [e for e in needed if e not in slab.residents]
            elif self.quantized:
                # quantized leaves are heterogeneous; mirror the manager
                if missed:
                    self._sync_slab(moe_idx)
                    _obs_sync(slab.buffers)
                missing = [e for e in needed if e not in slab.residents]
            else:
                missing, update = self._ensure_resident(moe_idx, needed)
                if update is not None and tr.enabled:
                    # slab fetches are fused into the next compute launch
                    # by design; under tracing, stage the host rows onto
                    # the device here so the fetch span measures the DMA
                    # instead of leaking it into the compute span
                    ws, slots = update
                    ws = jax.tree.map(jnp.asarray, ws)
                    jax.block_until_ready(ws)
                    update = (ws, slots)

        in_slab = [e for e in needed if e in slab.residents]
        G = _pad_bucket(len(in_slab))
        if 2 * G < slab.C:
            # few active slots: gather them and compute (G, N, ...) —
            # cheaper than streaming all C slots through the ref einsum.
            # Routing is sticky step-to-step, so the tiny index uploads
            # are cached by active-set key.
            key = (tuple(in_slab), tuple(int(slab.slot_of_expert[e])
                                         for e in in_slab))
            cache = slab._compact_maps
            maps = cache.get(key)
            if maps is None:
                E = self.moe_spec.num_experts
                group_slots = np.zeros(G, np.int32)
                soe_g = np.full(E, G, np.int32)
                group_expert = np.zeros(G, np.int32)
                for i, e in enumerate(in_slab):
                    group_slots[i] = slab.slot_of_expert[e]
                    soe_g[e] = i
                    group_expert[i] = e
                if len(cache) > 256:  # routing revisits few active sets
                    cache.clear()
                maps = cache[key] = (jnp.asarray(group_slots),
                                     jnp.asarray(soe_g),
                                     jnp.asarray(group_expert))
            variant = "compact"
        else:
            variant, maps = "full", slab.device_maps()
        return {"moe_idx": moe_idx, "layer": layer, "xa": xa, "h2f": h2f,
                "gates": gates, "eids": eids, "missing": missing,
                "degraded": degraded, "variant": variant, "maps": maps,
                "slab": slab, "update": update}

    def _finish_moe(self, p: dict):
        """Device half of the per-MoE-layer step, standalone: grouped
        compute (+ overflow for experts the slab could not serve: the
        |needed| > C spillover, degenerate C < K, cpu_execute,
        stream_all — transiently-on-device experts run through an
        ephemeral stacked bucket, or per expert with the fused dequant
        kernel when quantized) and the residual add."""
        layer, h2f, gates, eids = p["layer"], p["h2f"], p["gates"], p["eids"]
        kind = "moe_compact" if p["variant"] == "compact" else "moe"
        tr = get_tracer()
        with tr.span("moe.compute", layer=p["moe_idx"], variant=p["variant"]):
            y, p["slab"].buffers = self._jitted(kind, layer["name"])(
                layer["params"]["ffn"], layer["lora"], p["slab"].buffers,
                p["update"], *p["maps"], h2f, gates, eids,
            )
            _obs_sync(y)
        if p["missing"]:
            with tr.span("moe.spillover", layer=p["moe_idx"],
                         experts=len(p["missing"])):
                if self.quantized:
                    extra = self._eager_contrib(p["moe_idx"], layer, h2f,
                                                gates, eids, p["missing"])
                else:
                    extra = self._overflow_group(p["moe_idx"], layer, h2f,
                                                 gates, eids, p["missing"])
                y = _obs_sync(y + extra.astype(y.dtype))
        if p["degraded"]:
            with tr.span("moe.degraded", layer=p["moe_idx"],
                         experts=len(p["degraded"])):
                extra = self.little.contrib(p["moe_idx"], h2f, gates, eids,
                                            p["degraded"])
                y = _obs_sync(y + extra.astype(y.dtype))
        xa = p["xa"]
        B = xa.shape[0]
        return xa + y.reshape(B, -1, xa.shape[-1])

    def _overflow_group(self, moe_idx, layer, h2f, gates, eids, missing):
        E = self.moe_spec.num_experts
        bucket = _pad_bucket(len(missing))
        ws = self._stack_host(moe_idx, missing, bucket)
        soe = np.full(E, bucket, np.int32)
        se = np.zeros(bucket, np.int32)
        for i, e in enumerate(missing):
            soe[e] = i
            se[i] = e
        return self._jitted("moe_over", layer["name"])(
            layer["lora"], ws, jnp.asarray(soe), jnp.asarray(se),
            h2f, gates, eids,
        )

    def _eager_contrib(self, moe_idx, layer, h2f, gates, eids, missing):
        return self._per_expert_contrib(
            h2f, gates, eids, missing,
            lambda e: self._device_weights(self.host_store[moe_idx][e]),
            layer["lora"])

    # ------------------------------------------------------------------
    def _forward_layers_slab(self, x, positions, caches, decode_pos=None):
        """Pipelined layer walk for the slab engine: while layer l's MoE
        apply is still pending, the host finishes l's cache accounting,
        then ONE fused jitted call runs l's grouped compute together
        with layer l+1's attention/router (decode path, no overflow).
        Falls back to split calls at pipeline boundaries."""
        tr = get_tracer()
        pending = None
        for idx, layer in enumerate(self.layers):
            b = layer["spec"]
            if not (b.moe is not None and b.kind == "attn_moe"):
                if pending is not None:
                    x = self._finish_moe(pending)
                    pending = None
                x = self._block_forward(layer, x, positions, caches, idx,
                                        decode_pos)
                continue
            if pending is None:
                with tr.span("moe.pre", layer=layer["moe_idx"]):
                    if decode_pos is None:
                        xa, h2f, gates, eids, caches[idx] = self._jitted(
                            "pre_full", layer["name"])(
                                layer["params"], x, positions,
                                n_slots=self._n_slots)
                    else:
                        xa, h2f, gates, eids, caches[idx] = self._jitted(
                            "pre_dec", layer["name"])(
                                layer["params"], x, caches[idx], decode_pos)
                    _obs_sync(eids)
            elif (decode_pos is not None and not pending["missing"]
                  and not pending["degraded"]):
                # one launch: pending layer's grouped compute + THIS
                # layer's attention/router — the span charges it to the
                # pending layer (its compute dominates)
                pl = pending["layer"]
                with tr.span("moe.compute", layer=pending["moe_idx"],
                             variant=pending["variant"], fused=True):
                    (xa, h2f, gates, eids, caches[idx],
                     pending["slab"].buffers) = self._jitted_fused(
                        pl["name"], layer["name"],
                        pending["variant"] == "compact")(
                            pl["params"]["ffn"], pl["lora"],
                            pending["slab"].buffers, pending["update"],
                            pending["maps"], pending["h2f"], pending["gates"],
                            pending["eids"], pending["xa"], layer["params"],
                            caches[idx], decode_pos)
                    _obs_sync(eids)
            else:
                x = self._finish_moe(pending)
                with tr.span("moe.pre", layer=layer["moe_idx"]):
                    if decode_pos is None:
                        xa, h2f, gates, eids, caches[idx] = self._jitted(
                            "pre_full", layer["name"])(
                                layer["params"], x, positions,
                                n_slots=self._n_slots)
                    else:
                        xa, h2f, gates, eids, caches[idx] = self._jitted(
                            "pre_dec", layer["name"])(
                                layer["params"], x, caches[idx], decode_pos)
                    _obs_sync(eids)
            pending = self._prep_moe(layer["moe_idx"], layer, xa, h2f,
                                     gates, eids)
        if pending is not None:
            x = self._finish_moe(pending)
        return x

    def _block_forward(self, layer: dict, x, positions, caches, idx, decode_pos=None):
        """One block, full-seq (decode_pos None) or single-step. Under
        ``impl="slab"`` the attn_moe blocks never reach this method —
        :meth:`_forward_layers_slab` handles them."""
        cfg, b = self.cfg, layer["spec"]
        p = layer["params"]
        tr = get_tracer()
        if b.kind == "mamba":
            with tr.span("engine.block", kind="mamba", idx=idx):
                if decode_pos is None:
                    x2, aux = apply_block_full(p, cfg, b, x, positions, self.rt,
                                               want_cache=True, cache_slots=0)
                    caches[idx] = aux["kv"]
                    return _obs_sync(x2)
                from ..models.mamba2 import apply_mamba_decode

                h = rms_norm(p["ln1"], x, cfg.norm_eps)
                y, caches[idx] = apply_mamba_decode(p["mixer"], h, caches[idx],
                                                    b.ssm)
                return _obs_sync(x + y)

        # attention part
        from ..models.attention import attend_full, cache_from_prefill, decode_attend

        # attention + norms of a MoE block count toward that layer's
        # "pre" compute; dense blocks get their own engine.block span
        if b.moe is not None:
            ctx = tr.span("moe.pre", layer=layer["moe_idx"])
        else:
            ctx = tr.span("engine.block", kind=b.kind, idx=idx)
        with ctx:
            h = rms_norm(p["ln1"], x, cfg.norm_eps)
            if decode_pos is None:
                y, (k, v) = attend_full(p["mixer"], b.attn, h, positions,
                                        b.attn.window, return_kv=True, rt=self.rt)
                caches[idx] = cache_from_prefill(k, v, b.attn, self._n_slots)
            else:
                y, caches[idx] = decode_attend(p["mixer"], b.attn, h, caches[idx],
                                               decode_pos, b.attn.window)
            x = x + y
            h2 = _obs_sync(rms_norm(p["ln2"], x, cfg.norm_eps))
        if b.moe is not None:
            y2, _ = self._moe_forward(layer["moe_idx"], layer, h2)
        else:
            with tr.span("engine.block", kind="ffn", idx=idx):
                y2 = _obs_sync(apply_mlp(p["ffn"], h2))
        return x + y2

    # ------------------------------------------------------------------
    def generate(self, prompt_tokens, max_new_tokens: int,
                 prefix_embed=None, *, quality: float = 1.0,
                 deadline_s: Optional[float] = None) -> dict:
        """Greedy decoding. prompt_tokens (B, T) int32. Returns dict with
        tokens, metrics, throughput (Eq. 3 model).

        ``quality`` (the per-request quality-vs-latency dial, needs a
        little bank) sets the fraction of cache misses served by the big
        expert: 1.0 = always exact, 0.0 = always the little distillate.
        ``deadline_s`` bounds this call's serial Eq.-3 seconds: past
        ``pressure_frac`` of the budget remaining misses go all-little,
        and once the budget is spent decoding stops early
        (``stopped_early`` in the result)."""
        t0 = time.perf_counter()
        tr = get_tracer()
        cfg = self.cfg
        plan = get_fault_plan()
        self._gen_step = 0
        self._step_quality = quality if self.little is not None else 1.0
        elapsed = 0.0  # serial Eq.-3 seconds of this call's steps
        stopped_early = False
        toks = jnp.asarray(prompt_tokens)
        B, T = toks.shape
        L_moe = len(self.moe_layer_ids)
        self._n_slots = T + max_new_tokens + (prefix_embed.shape[1] if prefix_embed is not None else 0)

        # prefill
        with tr.span("engine.prefill", batch=B, prompt_len=T, impl=self.impl):
            self.metrics.begin_step(L_moe)
            with tr.span("engine.embed"):
                x = _obs_sync(self._embed_fn(self.params_top, toks,
                                             prefix_embed))
            Tt = x.shape[1]
            positions = jnp.broadcast_to(jnp.arange(Tt), (B, Tt))
            caches: List[Any] = [None] * len(self.layers)
            if self.impl == "slab":
                x = self._forward_layers_slab(x, positions, caches)
            else:
                for idx, layer in enumerate(self.layers):
                    x = self._block_forward(layer, x, positions, caches, idx)
            self.metrics.add_flops(self._flops_per_token * B * Tt)
            with tr.span("engine.logits"):
                next_tok = self._next_tok_fn(self.params_top, x)
                jax.block_until_ready(next_tok)
        # like wall_time, per-generate-call (the other counters accumulate)
        self.metrics.prefill_wall_time = time.perf_counter() - t0
        elapsed += self.metrics.serial_span(self.hw,
                                            len(self.metrics.step_flops) - 1)

        out_tokens = [next_tok]
        pos = jnp.asarray(Tt, jnp.int32)
        for step in range(max_new_tokens - 1):
            if deadline_s is not None:
                if elapsed >= deadline_s:
                    stopped_early = True
                    break
                if (self.little is not None
                        and elapsed >= self.pressure_frac * deadline_s):
                    self._step_quality = 0.0  # deadline pressure
            if plan.enabled:
                plan.maybe_crash("engine.decode")
                frac = plan.eviction_storm()
                if frac:
                    self._apply_storm(frac)
            self._gen_step = step + 1
            with tr.span("engine.decode_step", step=step, batch=B,
                         impl=self.impl):
                self.metrics.begin_step(L_moe)
                with tr.span("engine.embed"):
                    x = _obs_sync(self._embed_fn(self.params_top, next_tok))
                if self.impl == "slab":
                    x = self._forward_layers_slab(x, positions, caches,
                                                  decode_pos=pos)
                else:
                    for idx, layer in enumerate(self.layers):
                        x = self._block_forward(layer, x, positions, caches, idx, decode_pos=pos)
                with tr.span("engine.logits"):
                    next_tok = _obs_sync(self._next_tok_fn(self.params_top, x))
                out_tokens.append(next_tok)
                pos = pos + 1
                self.metrics.decode_tokens += 1
                self.metrics.add_flops(self._flops_per_token * B)
            elapsed += self.metrics.serial_span(
                self.hw, len(self.metrics.step_flops) - 1)
        self.metrics.decode_tokens += 1
        self.metrics.wall_time = time.perf_counter() - t0
        self._step_quality = 1.0

        m = self.metrics
        m.host_time = (
            m.host_executed * (3 * 2 * cfg.d_model * self.moe_spec.d_ff) / self.hw.host_flops
        )
        return {
            "tokens": jnp.concatenate(out_tokens, axis=1),
            "metrics": m,
            "stopped_early": stopped_early,
            "cache_stats": self.cache.stats(),
            "transfers_per_layer": self.cache.transfers_per_layer(),
            "throughput_tok_s": m.throughput(self.hw, batch=B),
            "throughput_overlapped_tok_s": m.throughput(self.hw, batch=B, overlap=True),
            "modeled_time_s": m.modeled_time(self.hw),
            "modeled_time_overlapped_s": m.modeled_time_overlapped(self.hw),
        }
