"""Combined MELINOE fine-tuning objective (Eq. 6):

    L = L_nll + lambda_cs * L_cs + lambda_rm * L_rm
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import MelinoeSpec
from .cache_sim import cache_sim_loss
from .rank_match import rank_match_loss


def melinoe_layer_losses(
    *,
    probs: jax.Array,  # (B, T, E) fine-tuned router distribution
    moe_h: Optional[jax.Array],  # (B, T, d) hidden states fed to the router
    base_router: Optional[jax.Array],  # (d, E) frozen base router weights
    spec: MelinoeSpec,
    cache_capacity: int,
    top_k: int,
):
    """Per-layer (cs, rm) contributions, each a scalar mean over (B, T)."""
    cs = cache_sim_loss(
        probs,
        top_k=top_k,
        gamma=spec.gamma,
        cache_capacity=cache_capacity,
        request_mode=spec.request_mode,
        impl=getattr(spec, "cs_impl", "scan"),
    )
    rm = jnp.zeros((), jnp.float32)
    if base_router is not None and moe_h is not None:
        # same_trajectory mode (DESIGN.md Sec 2): evaluate the frozen base
        # router on the fine-tuned model's (stop-grad) hidden states.
        h = lax.stop_gradient(moe_h.astype(jnp.float32))
        pb = jax.nn.softmax(h @ base_router.astype(jnp.float32), axis=-1)
        rm = rank_match_loss(pb, probs, rho=spec.rho, token_chunk=spec.rm_token_chunk)
    return cs, rm


def nll_loss(logits: jax.Array, targets: jax.Array, mask: Optional[jax.Array] = None):
    """Standard LM NLL. logits (B, T, V) fp32, targets (B, T) int32."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    if mask is None:
        return -ll.mean()
    mask = mask.astype(jnp.float32)
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def combine(nll, cs, rm, spec: MelinoeSpec):
    return nll + spec.lambda_cs * cs + spec.lambda_rm * rm
