"""MELINOE core: the paper's contribution as composable JAX modules."""
from .cache_sim import (cache_sim_loss, hard_cache_misses, replay_trace_misses,
                        soft_cache_states, topk_request)
from .expert_cache import LayerExpertCache, ModelExpertCache, simulate_trace
from .losses import combine, melinoe_layer_losses, nll_loss
from .lora import extract_base_routers, init_lora, lora_scale, melinoe_trainable_mask
from .offload_engine import (EngineMetrics, ExpertSlab, HardwareProfile,
                             OffloadedMoEEngine)
from .quant import QTensor, dequantize, qmatmul, quantize, quantize_linear
from .rank_match import inversion_count, rank_match_loss, rank_match_token

__all__ = [
    "cache_sim_loss", "hard_cache_misses", "replay_trace_misses",
    "soft_cache_states", "topk_request",
    "LayerExpertCache", "ModelExpertCache", "simulate_trace",
    "combine", "melinoe_layer_losses", "nll_loss",
    "extract_base_routers", "init_lora", "lora_scale", "melinoe_trainable_mask",
    "EngineMetrics", "ExpertSlab", "HardwareProfile", "OffloadedMoEEngine",
    "QTensor", "dequantize", "qmatmul", "quantize", "quantize_linear",
    "inversion_count", "rank_match_loss", "rank_match_token",
]
