"""Functional analogues of the paper's comparison systems (Sec 4.2),
expressed as policies over the same OffloadedMoEEngine substrate so
throughput differences come from the *policy*, not implementation noise.

  static_lru / static_lfu — fixed-size cache, no fine-tune, no predictor
                            (Mixtral-Offloading-like, minus its 3-bit quant)
  stream_all              — no cache: every activation transfers
                            (DeepSpeed-MoE-inference-like lower bound)
  profile_prefetch        — k-means over past routing profiles; prefetch
                            nearest centroid (MoE-Infinity-like)
  cpu_execute             — misses run on the host instead of transferring
                            (Fiddler-like)
  quant_cache             — INT4 residents -> larger effective C (FLoE/D.5)
  melinoe                 — fine-tuned checkpoint + predictor prefetch +
                            gamma/LFU cache (the paper's full system)

Composition (Table 5): pass the fine-tuned checkpoint to any baseline.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from ..configs.base import ModelConfig
from .offload_engine import HardwareProfile, OffloadedMoEEngine


@dataclass
class BaselineSpec:
    name: str
    policy: str = "lfu"
    gamma: float = 0.9
    quantized: bool = False
    stream_all: bool = False
    cpu_execute: bool = False
    use_predictor: bool = False
    capacity_mult: float = 1.0  # quant_cache fits ~3x more experts


BASELINES = {
    "static_lru": BaselineSpec("static_lru", policy="lru"),
    "static_lfu": BaselineSpec("static_lfu", policy="lfu"),
    "stream_all": BaselineSpec("stream_all", stream_all=True),
    "profile_prefetch": BaselineSpec("profile_prefetch", policy="lfu"),
    "cpu_execute": BaselineSpec("cpu_execute", cpu_execute=True),
    "quant_cache": BaselineSpec("quant_cache", quantized=True, capacity_mult=3.0),
    "melinoe": BaselineSpec("melinoe", policy="gamma", use_predictor=True),
}


def make_engine(cfg: ModelConfig, params, spec: BaselineSpec, *, capacity: int,
                hw: HardwareProfile = HardwareProfile(), lora=None,
                lora_scale: float = 1.0) -> OffloadedMoEEngine:
    E = cfg.moe_spec.num_experts
    return OffloadedMoEEngine(
        cfg,
        params,
        capacity=min(E, max(1, int(capacity * spec.capacity_mult))),
        policy=spec.policy,
        gamma=spec.gamma,
        quantized=spec.quantized,
        stream_all=spec.stream_all,
        cpu_execute=spec.cpu_execute,
        hw=hw,
        lora=lora,
        lora_scale=lora_scale,
    )


# ---------------------------------------------------------------------------
# MoE-Infinity-like profile prefetcher: k-means over past per-sequence
# activation profiles; prefetch the centroid nearest to the running profile.
# ---------------------------------------------------------------------------


class ProfilePrefetcher:
    def __init__(self, n_clusters: int = 8, seed: int = 0):
        self.k = n_clusters
        self.seed = seed
        self.centroids: Optional[np.ndarray] = None  # (k, L*E)

    def fit(self, profiles: np.ndarray, iters: int = 25):
        """profiles (N, L, E) past per-sequence mean activations."""
        X = profiles.reshape(profiles.shape[0], -1).astype(np.float64)
        rng = np.random.default_rng(self.seed)
        k = min(self.k, X.shape[0])
        cent = X[rng.choice(X.shape[0], k, replace=False)]
        for _ in range(iters):
            d = ((X[:, None] - cent[None]) ** 2).sum(-1)
            assign = d.argmin(-1)
            for c in range(k):
                m = assign == c
                if m.any():
                    cent[c] = X[m].mean(0)
        self.centroids = cent
        self._shape = profiles.shape[1:]
        return self

    def predict_scores(self, partial_profile: np.ndarray) -> np.ndarray:
        """partial_profile (L, E) -> predicted (L, E) scores."""
        assert self.centroids is not None, "fit() first"
        x = partial_profile.reshape(-1)
        d = ((self.centroids - x[None]) ** 2).sum(-1)
        return self.centroids[d.argmin()].reshape(self._shape)
