"""LoRA adapters for expert up/down projections + the MELINOE trainable
partition (paper Sec 3.1.1: full updates on router weights and expert
gate projections; LoRA rank-32 on expert up/down; everything else frozen).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import MelinoeSpec, ModelConfig
from ..models.common import dense_init

LORA_TARGETS = ("wu", "wd")  # expert up / down projections


def lora_scale(spec: MelinoeSpec) -> float:
    return spec.lora_alpha / spec.lora_rank


def init_lora(key, cfg: ModelConfig, spec: MelinoeSpec, dtype=jnp.float32):
    """Returns a pytree mirroring params["groups"], containing adapters
    only at MoE positions: {g: {p: {"wu": {"a","b"}, "wd": {"a","b"}}}}.

    a ~ N(0, 1/d); b = 0 (standard LoRA init: delta starts at zero)."""
    r = spec.lora_rank
    tree: Dict[str, Any] = {}
    for gi, g in enumerate(cfg.layout):
        gtree: Dict[str, Any] = {}
        for pi, bname in enumerate(g.pattern):
            b = cfg.block_defs[bname]
            if b.moe is None:
                continue
            E, d, f = b.moe.num_experts, cfg.d_model, b.moe.d_ff
            dims = {"wu": (d, f), "wd": (f, d)}
            ptree = {}
            for t in LORA_TARGETS:
                din, dout = dims[t]
                k1 = jax.random.fold_in(key, hash((gi, pi, t)) % (2**31))
                ks = jax.random.split(k1, g.repeats * E).reshape(g.repeats, E)
                a = jax.vmap(jax.vmap(lambda kk: dense_init(kk, din, r, dtype)))(ks)
                ptree[t] = {
                    "a": a,  # (R, E, din, r)
                    "b": jnp.zeros((g.repeats, E, r, dout), dtype),
                }
            gtree[f"p{pi}"] = ptree
        tree[f"g{gi}"] = gtree
    return tree


# ---------------------------------------------------------------------------
# Trainable partition for MELINOE fine-tuning
# ---------------------------------------------------------------------------


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def melinoe_trainable_mask(params) -> Any:
    """Bool pytree: True for router weights and expert gate projections
    (full update); everything else in the base params is frozen.
    LoRA params are trained in full (handled as a separate tree)."""

    def mark(path, leaf):
        s = _path_str(path)
        if "/ffn/router" in s:
            return True
        # expert gate projection: ffn/wg (stacked per expert). Exclude the
        # dense-MLP wg (non-MoE blocks) by requiring 3+ dims (E, d, f).
        if s.endswith("/ffn/wg") and hasattr(leaf, "ndim") and leaf.ndim >= 4:
            return True
        return False

    return jax.tree_util.tree_map_with_path(mark, params)


def apply_mask(tree, mask, frozen_value=0.0):
    """Zero (or replace) leaves where mask is False — used to freeze grads."""
    return jax.tree.map(
        lambda g, m: g if m else jnp.zeros_like(g) if frozen_value == 0.0 else g * frozen_value,
        tree,
        mask,
    )


def extract_base_routers(params, cfg: ModelConfig):
    """Stacked frozen router weights per group/position for the
    same_trajectory rank-matching mode."""
    out = {}
    for gi, g in enumerate(cfg.layout):
        gname = f"g{gi}"
        gout = {}
        for pi, bname in enumerate(g.pattern):
            if cfg.block_defs[bname].moe is None:
                continue
            gout[f"p{pi}"] = jax.lax.stop_gradient(
                params["groups"][gname][f"p{pi}"]["ffn"]["router"]
            )
        out[gname] = gout
    return out
