"""Expert activation predictor Psi (paper Sec 3.1.2).

Psi_EMB: the paper uses BGE-Base-EN-v1.5 (768-dim). Offline container =>
a frozen deterministic *bag-of-embedding* encoder with the same
interface: a fixed random table (seeded) indexed by token id, mean-pooled
over the prompt. DESIGN.md Sec 10 records the substitution.

Psi_MLP: 2-layer MLP 768 -> 1024 -> L*E trained with row-wise KL against
the per-layer mean router distribution Y(q) (Table 8 hyper-parameters:
SGD, momentum 0.9, lr 2e-4, batch 16, 10 epochs).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

D_EMB = 768
D_HIDDEN = 1024


# ---------------------------------------------------------------------------
# Psi_EMB (frozen stub with the BGE interface)
# ---------------------------------------------------------------------------


class PromptEmbedder:
    def __init__(self, vocab: int, d_emb: int = D_EMB, seed: int = 17):
        rng = np.random.default_rng(seed)
        self.table = jnp.asarray(
            rng.standard_normal((vocab, d_emb), np.float32) / np.sqrt(d_emb)
        )

    def __call__(self, tokens) -> jax.Array:
        """tokens (T,) or (B, T) -> (d_emb,) or (B, d_emb) mean-pooled."""
        emb = self.table[tokens]
        return emb.mean(axis=-2)


# ---------------------------------------------------------------------------
# Psi_MLP
# ---------------------------------------------------------------------------


def init_predictor(key, n_layers: int, n_experts: int, d_emb: int = D_EMB,
                   d_hidden: int = D_HIDDEN):
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (d_emb, d_hidden), jnp.float32) / np.sqrt(d_emb),
        "b1": jnp.zeros((d_hidden,), jnp.float32),
        "w2": jax.random.normal(k2, (d_hidden, n_layers * n_experts), jnp.float32)
        / np.sqrt(d_hidden),
        "b2": jnp.zeros((n_layers * n_experts,), jnp.float32),
        "_dims": (n_layers, n_experts),
    }


def predictor_logits(params, emb) -> jax.Array:
    """emb (..., d_emb) -> (..., L, E) unnormalized preference scores."""
    L, E = params["_dims"]
    h = jax.nn.relu(emb @ params["w1"] + params["b1"])
    out = h @ params["w2"] + params["b2"]
    return out.reshape(*emb.shape[:-1], L, E)


def predictor_kl_loss(params, emb, target) -> jax.Array:
    """Row-wise KL(target || softmax(pred)). target (..., L, E) normalized."""
    logits = predictor_logits(params, emb)
    logq = jax.nn.log_softmax(logits, axis=-1)
    t = target / jnp.maximum(target.sum(-1, keepdims=True), 1e-9)
    kl = (t * (jnp.log(jnp.maximum(t, 1e-9)) - logq)).sum(-1)
    return kl.mean()


def train_predictor(
    params,
    embs: jax.Array,  # (N, d_emb)
    targets: jax.Array,  # (N, L, E) per-layer mean router probs Y(q)
    *,
    lr: float = 2e-4,
    momentum: float = 0.9,
    epochs: int = 10,
    batch_size: int = 16,
    seed: int = 0,
) -> Tuple[dict, List[float]]:
    """SGD+momentum per paper Table 8. Returns (params, loss history)."""
    dims = params["_dims"]
    weights = {k: v for k, v in params.items() if k != "_dims"}
    vel = jax.tree.map(jnp.zeros_like, weights)

    def loss_fn(w, e, t):
        return predictor_kl_loss({**w, "_dims": dims}, e, t)

    @jax.jit
    def step(w, v, e, t):
        loss, g = jax.value_and_grad(loss_fn)(w, e, t)
        v = jax.tree.map(lambda vi, gi: momentum * vi + gi, v, g)
        w = jax.tree.map(lambda wi, vi: wi - lr * vi, w, v)
        return w, v, loss

    n = embs.shape[0]
    rng = np.random.default_rng(seed)
    history = []
    for _ in range(epochs):
        order = rng.permutation(n)
        ep_loss = 0.0
        nb = 0
        for s in range(0, n, batch_size):
            idx = order[s : s + batch_size]
            weights, vel, loss = step(weights, vel, embs[idx], targets[idx])
            ep_loss += float(loss)
            nb += 1
        history.append(ep_loss / max(nb, 1))
    return {**weights, "_dims": dims}, history


def predict_topc(params, emb, capacity: int) -> np.ndarray:
    """emb (d_emb,) -> (L, C) predicted Top-C expert ids per layer (Eq. 7)."""
    scores = predictor_logits(params, emb)
    return np.asarray(jnp.argsort(-scores, axis=-1)[..., :capacity])


def predict_scores(params, emb) -> np.ndarray:
    return np.asarray(predictor_logits(params, emb))


def build_targets(probs_list: List[jax.Array]) -> jax.Array:
    """Stacked per-(group,position) router probs [(R, B, T, E), ...] ->
    Y (B, L, E): per-layer mean over tokens (Sec 3.1.2)."""
    per_layer = []
    for p in probs_list:
        R, B, T, E = p.shape
        per_layer.append(p.mean(axis=2).transpose(1, 0, 2))  # (B, R, E)
    return jnp.concatenate(per_layer, axis=1)  # (B, L_moe, E)
