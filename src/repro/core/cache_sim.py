"""Cache-simulation loss L_cs (paper Sec 3.1.1, App C.1).

A differentiable *soft cache state* c^(t) in R^E_{>=0} with ||c||_1 = C is
maintained by the Z-normalized recursion of Prop C.3:

    c^(t+1) = (gamma * Z^(t) * c^(t) + r^(t)) / Z^(t+1)
    Z^(t+1) = gamma * Z^(t) + K / C

and the loss is the cache-miss proxy  mean_t <r^(t), 1 - c^(t)>.

``r`` is the Top-K request vector. Top-K is non-differentiable, so two
estimators are provided (DESIGN.md Sec 2):
  * soft    — r = Top-K-masked probabilities renormalized to L1 mass K
              (fully differentiable; default)
  * hard_st — straight-through: forward value is the binary mask,
              gradient flows through the masked probabilities.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def topk_request(probs: jax.Array, k: int, mode: str = "soft") -> jax.Array:
    """probs (..., E) -> request vector r (..., E) with ||r||_1 = K."""
    _, eids = lax.top_k(probs, k)
    mask = jax.nn.one_hot(eids, probs.shape[-1], dtype=probs.dtype).sum(-2)
    if mode == "hard":
        return mask
    pm = probs * mask
    if mode == "soft":
        return pm * (k / jnp.maximum(pm.sum(-1, keepdims=True), 1e-9))
    if mode == "hard_st":
        scaled = pm * (k / jnp.maximum(pm.sum(-1, keepdims=True), 1e-9))
        return mask + scaled - lax.stop_gradient(scaled)
    raise ValueError(f"unknown request mode {mode!r}")


def soft_cache_states(r: jax.Array, gamma: float, cache_capacity: int, top_k: int,
                      init: jax.Array | None = None):
    """r (T, E) requests -> (c (T, E), final_c (E,)).

    c[t] is the cache state *seen by* token t (i.e. built from requests
    < t). Uniform initialization with ||c^(1)||_1 = C (App C.1 option
    that avoids the cache-fill phase)."""
    T, E = r.shape
    C = float(cache_capacity)
    if init is None:
        init = jnp.full((E,), C / E, jnp.float32)
    z0 = jnp.asarray(1.0, jnp.float32)

    def body(carry, r_t):
        c, z = carry
        z_new = gamma * z + top_k / C
        c_new = (gamma * z * c + r_t) / z_new
        return (c_new, z_new), c

    (c_fin, _), cs = lax.scan(body, (init.astype(jnp.float32), z0), r.astype(jnp.float32))
    return cs, c_fin


def soft_cache_states_assoc(r: jax.Array, gamma: float, cache_capacity: int,
                            init: jax.Array | None = None):
    """O(log T)-depth equivalent of :func:`soft_cache_states`.

    Beyond-paper optimization (EXPERIMENTS.md §Perf): the paper's Z-
    normalized recursion forces a T-step sequential scan inside every MoE
    layer's loss. But by Prop C.3 the state is just the gamma-discounted
    count re-normalized to L1 mass C:

        Count_t = gamma^{t-1} * Count_1 + sum_{i<t} gamma^{t-1-i} r_i
        c_t     = C * Count_t / ||Count_t||_1

    and the Count recursion is a constant-coefficient linear recurrence,
    so ``lax.associative_scan`` evaluates all T states in log2(T) parallel
    steps — identical values, no sequential dependency."""
    T, E = r.shape
    C = float(cache_capacity)
    if init is None:
        init = jnp.full((E,), C / E, jnp.float32)
    rf = r.astype(jnp.float32)
    # pairs (a, b) meaning x -> a*x + b; combine right-after-left
    a0 = jnp.full((T,), gamma, jnp.float32)
    b0 = jnp.concatenate([init[None], rf[:-1]], axis=0)  # b_t carries r_{t-1}

    def combine(left, right):
        (a1, b1), (a2, b2) = left, right
        return a1 * a2, a2[..., None] * b1 + b2

    # prefix over t of: Count_t = gamma^{t-1} Count_1' + ... ; treat the
    # initial state via b0[0] = init with a acting multiplicatively.
    aa, bb = lax.associative_scan(combine, (a0, b0))
    # Count_t = aa_t * 0 + bb_t with Count_0 folded into b0[0]... but the
    # first element's 'a' multiplies the (zero) pre-state, so bb IS Count.
    counts = bb
    c = counts * (C / jnp.maximum(counts.sum(-1, keepdims=True), 1e-30))
    count_fin = gamma * counts[-1] + rf[-1]  # state after the last request
    c_fin = count_fin * (C / jnp.maximum(count_fin.sum(), 1e-30))
    return c, c_fin


def cache_sim_loss(
    probs: jax.Array,
    *,
    top_k: int,
    gamma: float,
    cache_capacity: int,
    request_mode: str = "soft",
    impl: str = "assoc",
) -> jax.Array:
    """probs (B, T, E) router distributions of ONE layer -> scalar:
    mean over batch of (1/T) sum_t <r_t, 1 - c_t>  (Eq. 4, one-layer slice).

    ``impl``: "scan" (paper-faithful sequential recursion) or "assoc"
    (numerically identical associative-scan evaluation, log-depth)."""
    r = topk_request(probs.astype(jnp.float32), top_k, request_mode)

    def per_seq(r_seq):
        if impl == "assoc":
            cs, _ = soft_cache_states_assoc(r_seq, gamma, cache_capacity)
        else:
            cs, _ = soft_cache_states(r_seq, gamma, cache_capacity, top_k)
        miss = (r_seq * (1.0 - cs)).sum(-1)  # (T,)
        return miss.mean()

    return jax.vmap(per_seq)(r).mean()


# ---------------------------------------------------------------------------
# Hard (non-differentiable) counterparts — Def C.1, used by tests and the
# offload engine to cross-check the soft proxy.
# ---------------------------------------------------------------------------


def hard_cache_misses(r_hard: jax.Array, gamma: float, cache_capacity: int,
                      init_counts: jax.Array | None = None) -> jax.Array:
    """Binary requests r (T, E) -> total misses under the gamma-discounted
    Top-C cache of Def C.1. Returns scalar miss count."""
    T, E = r_hard.shape
    C = cache_capacity
    counts0 = (
        jnp.full((E,), C / E, jnp.float32) if init_counts is None else init_counts
    )

    def body(counts, r_t):
        # cache = Top-C of discounted counts (state before this request)
        _, top = lax.top_k(counts, C)
        in_cache = jnp.zeros((E,), bool).at[top].set(True)
        miss = (r_t * (~in_cache)).sum()
        counts_new = gamma * counts + r_t
        return counts_new, miss

    _, misses = lax.scan(body, counts0, r_hard.astype(jnp.float32))
    return misses.sum()


def replay_trace_misses(routing, cache_capacity: int, policy: str = "gamma",
                        gamma: float = 0.9,
                        num_experts: int | None = None) -> int:
    """Replay an integer Top-K id trace (T, K) through the REAL
    eviction-based cache (``LayerExpertCache``) in one vectorized
    ``access_batch`` call and return the miss count.

    Complements :func:`hard_cache_misses` (the lazy Top-C-of-counts
    formulation of Def C.1): this is the cache the offload engine
    actually runs, so it is the ground truth the soft proxy must rank
    consistently with."""
    import numpy as np

    from .expert_cache import LayerExpertCache

    routing = np.asarray(routing)
    E = num_experts or max(int(routing.max()) + 1, cache_capacity)
    cache = LayerExpertCache(E, cache_capacity, policy, gamma)
    cache.access_batch(routing)
    return cache.misses
