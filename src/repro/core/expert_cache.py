"""Host-side expert cache policies (Def C.1) and the per-layer cache
manager used by the offloaded inference engine.

Policies
--------
* ``lru``   — evict least-recently-used (gamma -> 0 limit)
* ``lfu``   — evict least-frequently-used (gamma = 1 limit)
* ``gamma`` — Def C.1: gamma-discounted request counts; the cache is the
              Top-C of the counts; lazy updates (Remark C.2).

The manager counts misses == host->device transfers (Eq. 3).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np

from ..obs.trace import get_tracer


class LayerExpertCache:
    """Cache of expert ids for one MoE layer, capacity C."""

    def __init__(self, num_experts: int, capacity: int, policy: str = "lfu",
                 gamma: float = 0.9, layer_id: int = -1):
        assert 0 < capacity <= num_experts
        self.E = num_experts
        self.C = capacity
        self.policy = policy
        self.gamma = gamma
        self.layer_id = layer_id
        self.counts = np.zeros(num_experts, np.float64)  # lfu / gamma
        self.last_used = np.full(num_experts, -1, np.int64)  # lru
        self.resident: set[int] = set()
        self.step = 0
        self.misses = 0
        self.hits = 0
        self.evictions = 0
        # suppresses per-token trace instants while a batched entry point
        # aggregates them into one event
        self._nested = False

    def _traced(self, name: str, fn, *args):
        """Run ``fn`` and emit one aggregated hit/miss/evict instant."""
        h0, m0, v0 = self.hits, self.misses, self.evictions
        self._nested = True
        try:
            out = fn(*args)
        finally:
            self._nested = False
        get_tracer().instant(name, layer=self.layer_id,
                             hits=self.hits - h0, misses=self.misses - m0,
                             evictions=self.evictions - v0)
        return out

    # -- setup ------------------------------------------------------------
    def prefill(self, expert_ids: Iterable[int]) -> int:
        if get_tracer().enabled and not self._nested:
            return self._traced("cache.prefill", self._prefill, expert_ids)
        return self._prefill(expert_ids)

    def _prefill(self, expert_ids: Iterable[int]) -> int:
        """Proactively load experts (predictor prefetch). Returns #loaded.

        Evicts as needed so residency never exceeds capacity C, even when
        the cache is already warm; the incoming prefetch set is protected
        from its own evictions."""
        wanted = [int(e) for e in list(expert_ids)[: self.C]]
        protect = set(wanted)
        loaded = 0
        for e in wanted:
            if e in self.resident:
                continue
            while len(self.resident) >= self.C:
                victim = self._evict_candidate(protect)
                self.resident.discard(victim)
                self.evictions += 1
            self.resident.add(e)
            loaded += 1
        # prefetched experts get a count/recency credit so they are not
        # instantly evicted (only the wanted set: crediting every resident
        # would re-inflate stale LFU counts and distort eviction order)
        for e in wanted:
            self.counts[e] = max(self.counts[e], 1.0)
            self.last_used[e] = self.step
        return loaded

    # -- durable state (recovery checkpoints) -------------------------------
    def state(self) -> dict:
        """Snapshot of the policy scores + resident set — what a warm
        revival needs to rebuild eviction order AND physical residency."""
        return {
            "resident": sorted(int(e) for e in self.resident),
            "counts": self.counts.copy(),
            "last_used": self.last_used.copy(),
            "step": self.step,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
        }

    def load_state(self, state: dict, *, resident: bool = True) -> None:
        """Restore a :meth:`state` snapshot. ``resident=False`` restores
        only the policy scores/stats (cold restart keeps the accounting
        but pays the demand misses again)."""
        self.counts = np.asarray(state["counts"], np.float64).copy()
        self.last_used = np.asarray(state["last_used"], np.int64).copy()
        self.step = int(state["step"])
        self.hits = int(state["hits"])
        self.misses = int(state["misses"])
        self.evictions = int(state["evictions"])
        self.resident = set(int(e) for e in state["resident"]) if resident \
            else set()

    def audit(self) -> List[str]:
        """Internal-consistency check (watchdog contract). Returns
        violation strings, empty when healthy."""
        v = []
        if len(self.resident) > self.C:
            v.append(f"resident {len(self.resident)} > capacity {self.C}")
        bad = [e for e in self.resident if not (0 <= e < self.E)]
        if bad:
            v.append(f"resident ids out of range: {sorted(bad)}")
        if not np.all(np.isfinite(self.counts)) or np.any(self.counts < 0):
            v.append("policy counts non-finite or negative")
        if min(self.hits, self.misses, self.evictions) < 0:
            v.append(f"negative stats: hits={self.hits} misses={self.misses} "
                     f"evictions={self.evictions}")
        return v

    # -- per-token access ---------------------------------------------------
    def _evict_candidate(self, protect: set) -> int:
        if len(self.resident) <= 64:  # typical C: python min beats numpy
            free = [e for e in self.resident if e not in protect] or list(
                self.resident)
            key = self.last_used if self.policy == "lru" else self.counts
            return min(free, key=key.__getitem__)
        res = np.fromiter(self.resident, int)
        free = res[~np.isin(res, list(protect))] if protect else res
        if free.size == 0:
            free = res  # degenerate: everything protected
        if self.policy == "lru":
            return int(free[np.argmin(self.last_used[free])])
        return int(free[np.argmin(self.counts[free])])  # lfu / gamma

    def access(self, requested: Sequence[int]) -> List[int]:
        """One token's Top-K expert request. Returns the list of MISSED
        expert ids (each miss = one transfer)."""
        if get_tracer().enabled and not self._nested:
            return self._traced("cache.access", self._access, requested)
        return self._access(requested)

    def _access(self, requested: Sequence[int]) -> List[int]:
        self.step += 1
        requested = [int(e) for e in requested]
        if self.policy == "gamma":
            self.counts *= self.gamma
        missed = []
        protect = set(requested)
        for e in requested:
            if e in self.resident:
                self.hits += 1
            else:
                missed.append(e)
                self.misses += 1
                while len(self.resident) >= self.C:
                    victim = self._evict_candidate(protect)
                    self.resident.discard(victim)
                    self.evictions += 1
                self.resident.add(e)
            self.counts[e] += 1.0
            self.last_used[e] = self.step
        return missed

    def access_batch(self, requests) -> List[int]:
        """Batched token accesses: ``requests`` (N, K) int expert ids, in
        token order. Metrics-equivalent to N sequential :meth:`access`
        calls — identical hits/misses/evictions, resident set, counts and
        recency — but the all-hit spans (the common warm-cache case) are
        processed in vectorized numpy instead of per-token Python.

        Returns the concatenated missed-expert list (token order, with
        duplicates when an expert is missed, evicted, and missed again
        inside the same batch) — each entry is one host->device transfer.
        """
        if get_tracer().enabled and not self._nested:
            return self._traced("cache.access", self._access_batch, requests)
        return self._access_batch(requests)

    def _access_batch(self, requests) -> List[int]:
        req = np.asarray(requests, dtype=np.int64)
        if req.ndim == 1:
            req = req[None]
        N, K = req.shape
        if N == 1:  # decode batches of one: the sequential step IS the batch
            return self.access(req[0])
        missed: List[int] = []
        rows = req.tolist()  # python-set membership beats np.isin per row
        n = 0
        while n < N:
            # leading hit span: no eviction can trigger before the first
            # non-hit token, so the resident set is constant across it —
            # detect in O(span * K), bookkeep vectorized
            res = self.resident
            m = n
            while m < N and all(e in res for e in rows[m]):
                m += 1
            if m > n:
                self._hit_span(req[n:m])
                n = m
            if n < N:  # first token with a miss: exact sequential step
                missed.extend(self.access(req[n]))
                n += 1
        return missed

    def _hit_span(self, req: np.ndarray) -> None:
        """Bookkeeping for a span of tokens whose requests all hit. Bit-
        identical to the sequential loop: per token the gamma decay is one
        whole-array multiply and each request adds 1.0 once."""
        n, K = req.shape
        self.hits += n * K
        if self.policy == "gamma":
            for t in range(n):  # keep the sequential decay/add FP order
                self.counts *= self.gamma
                np.add.at(self.counts, req[t], 1.0)
        else:
            np.add.at(self.counts, req.reshape(-1), 1.0)
        steps = np.repeat(self.step + 1 + np.arange(n, dtype=np.int64), K)
        np.maximum.at(self.last_used, req.reshape(-1), steps)
        self.step += n


@dataclass
class CacheStats:
    misses: int
    hits: int
    evictions: int

    @property
    def transfers(self) -> int:
        return self.misses

    @property
    def hit_rate(self) -> float:
        t = self.hits + self.misses
        return self.hits / t if t else 0.0


class ModelExpertCache:
    """One LayerExpertCache per MoE layer."""

    def __init__(self, n_layers: int, num_experts: int, capacity: int,
                 policy: str = "lfu", gamma: float = 0.9):
        self.layers = [
            LayerExpertCache(num_experts, capacity, policy, gamma, layer_id=l)
            for l in range(n_layers)
        ]

    def prefill_from_scores(self, scores: np.ndarray) -> int:
        """scores (L, E) predictor output -> preload Top-C per layer."""
        loaded = 0
        for l, cache in enumerate(self.layers):
            top = np.argsort(-scores[l])[: cache.C]
            loaded += cache.prefill(top)
        return loaded

    def access(self, layer: int, requested: Sequence[int]) -> List[int]:
        return self.layers[layer].access(requested)

    def access_batch(self, layer: int, requests) -> List[int]:
        return self.layers[layer].access_batch(requests)

    def stats(self) -> CacheStats:
        return CacheStats(
            misses=sum(c.misses for c in self.layers),
            hits=sum(c.hits for c in self.layers),
            evictions=sum(c.evictions for c in self.layers),
        )

    def transfers_per_layer(self) -> float:
        return float(np.mean([c.misses for c in self.layers]))

    def reset_stats(self):
        for c in self.layers:
            c.misses = c.hits = c.evictions = 0

    def state(self) -> List[dict]:
        """Per-layer :meth:`LayerExpertCache.state` snapshots."""
        return [c.state() for c in self.layers]

    def load_state(self, states: Sequence[dict], *, resident: bool = True) -> None:
        assert len(states) == len(self.layers), (len(states), len(self.layers))
        for c, st in zip(self.layers, states):
            c.load_state(st, resident=resident)

    def audit(self) -> List[str]:
        return [f"layer {c.layer_id}: {msg}"
                for c in self.layers for msg in c.audit()]

    def publish(self, registry=None, **labels) -> None:
        """Export per-layer and aggregate hit/miss/evict gauges onto a
        :class:`~repro.obs.registry.MetricsRegistry` (global by default)."""
        if registry is None:
            from ..obs.registry import REGISTRY as registry
        for c in self.layers:
            for nm, v in (("cache_hits", c.hits), ("cache_misses", c.misses),
                          ("cache_evictions", c.evictions)):
                registry.gauge(nm, "expert cache events",
                               layer=c.layer_id, **labels).set(v)
        s = self.stats()
        registry.gauge("cache_hit_rate", "aggregate expert cache hit rate",
                       **labels).set(s.hit_rate)


def simulate_trace(routing: np.ndarray, capacity: int, policy: str = "lfu",
                   gamma: float = 0.9, prefetch: Optional[np.ndarray] = None) -> CacheStats:
    """Replay a routing trace.

    routing: (T, L, K) int expert ids per token/layer.
    prefetch: optional (L, E) scores for proactive cache init."""
    T, L, K = routing.shape
    E = int(routing.max()) + 1
    mc = ModelExpertCache(L, E, capacity, policy, gamma)
    if prefetch is not None:
        mc.prefill_from_scores(prefetch)
    # per-layer caches are independent, so the token loop batches away:
    # one access_batch per layer replays that layer's whole (T, K) trace
    for l in range(L):
        mc.access_batch(l, routing[:, l])
    return mc.stats()
