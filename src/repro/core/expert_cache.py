"""Host-side expert cache policies (Def C.1) and the per-layer cache
manager used by the offloaded inference engine.

Policies
--------
* ``lru``   — evict least-recently-used (gamma -> 0 limit)
* ``lfu``   — evict least-frequently-used (gamma = 1 limit)
* ``gamma`` — Def C.1: gamma-discounted request counts; the cache is the
              Top-C of the counts; lazy updates (Remark C.2).

The manager counts misses == host->device transfers (Eq. 3).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np


class LayerExpertCache:
    """Cache of expert ids for one MoE layer, capacity C."""

    def __init__(self, num_experts: int, capacity: int, policy: str = "lfu",
                 gamma: float = 0.9):
        assert 0 < capacity <= num_experts
        self.E = num_experts
        self.C = capacity
        self.policy = policy
        self.gamma = gamma
        self.counts = np.zeros(num_experts, np.float64)  # lfu / gamma
        self.last_used = np.full(num_experts, -1, np.int64)  # lru
        self.resident: set[int] = set()
        self.step = 0
        self.misses = 0
        self.hits = 0
        self.evictions = 0

    # -- setup ------------------------------------------------------------
    def prefill(self, expert_ids: Iterable[int]) -> int:
        """Proactively load experts (predictor prefetch). Returns #loaded.

        Evicts as needed so residency never exceeds capacity C, even when
        the cache is already warm; the incoming prefetch set is protected
        from its own evictions."""
        wanted = [int(e) for e in list(expert_ids)[: self.C]]
        protect = set(wanted)
        loaded = 0
        for e in wanted:
            if e in self.resident:
                continue
            while len(self.resident) >= self.C:
                victim = self._evict_candidate(protect)
                self.resident.discard(victim)
                self.evictions += 1
            self.resident.add(e)
            loaded += 1
        # prefetched experts get a count/recency credit so they are not
        # instantly evicted
        for e in self.resident:
            self.counts[e] = max(self.counts[e], 1.0)
            self.last_used[e] = self.step
        return loaded

    # -- per-token access ---------------------------------------------------
    def _evict_candidate(self, protect: set) -> int:
        res = np.fromiter(self.resident, int)
        free = res[~np.isin(res, list(protect))] if protect else res
        if free.size == 0:
            free = res  # degenerate: everything protected
        if self.policy == "lru":
            return int(free[np.argmin(self.last_used[free])])
        return int(free[np.argmin(self.counts[free])])  # lfu / gamma

    def access(self, requested: Sequence[int]) -> List[int]:
        """One token's Top-K expert request. Returns the list of MISSED
        expert ids (each miss = one transfer)."""
        self.step += 1
        requested = [int(e) for e in requested]
        if self.policy == "gamma":
            self.counts *= self.gamma
        missed = []
        protect = set(requested)
        for e in requested:
            if e in self.resident:
                self.hits += 1
            else:
                missed.append(e)
                self.misses += 1
                while len(self.resident) >= self.C:
                    victim = self._evict_candidate(protect)
                    self.resident.discard(victim)
                    self.evictions += 1
                self.resident.add(e)
            self.counts[e] += 1.0
            self.last_used[e] = self.step
        return missed


@dataclass
class CacheStats:
    misses: int
    hits: int
    evictions: int

    @property
    def transfers(self) -> int:
        return self.misses

    @property
    def hit_rate(self) -> float:
        t = self.hits + self.misses
        return self.hits / t if t else 0.0


class ModelExpertCache:
    """One LayerExpertCache per MoE layer."""

    def __init__(self, n_layers: int, num_experts: int, capacity: int,
                 policy: str = "lfu", gamma: float = 0.9):
        self.layers = [
            LayerExpertCache(num_experts, capacity, policy, gamma)
            for _ in range(n_layers)
        ]

    def prefill_from_scores(self, scores: np.ndarray) -> int:
        """scores (L, E) predictor output -> preload Top-C per layer."""
        loaded = 0
        for l, cache in enumerate(self.layers):
            top = np.argsort(-scores[l])[: cache.C]
            loaded += cache.prefill(top)
        return loaded

    def access(self, layer: int, requested: Sequence[int]) -> List[int]:
        return self.layers[layer].access(requested)

    def stats(self) -> CacheStats:
        return CacheStats(
            misses=sum(c.misses for c in self.layers),
            hits=sum(c.hits for c in self.layers),
            evictions=sum(c.evictions for c in self.layers),
        )

    def transfers_per_layer(self) -> float:
        return float(np.mean([c.misses for c in self.layers]))

    def reset_stats(self):
        for c in self.layers:
            c.misses = c.hits = c.evictions = 0


def simulate_trace(routing: np.ndarray, capacity: int, policy: str = "lfu",
                   gamma: float = 0.9, prefetch: Optional[np.ndarray] = None) -> CacheStats:
    """Replay a routing trace.

    routing: (T, L, K) int expert ids per token/layer.
    prefetch: optional (L, E) scores for proactive cache init."""
    T, L, K = routing.shape
    E = int(routing.max()) + 1
    mc = ModelExpertCache(L, E, capacity, policy, gamma)
    if prefetch is not None:
        mc.prefill_from_scores(prefetch)
    for t in range(T):
        for l in range(L):
            mc.access(l, routing[t, l])
    return mc.stats()
