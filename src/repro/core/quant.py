"""HQQ-style INT4 group quantization (paper Sec 3.2: resident experts are
kept in HQQ INT4 to raise effective cache capacity).

Weights are quantized per *group* along the contraction dimension
(group_size consecutive elements share a scale and zero-point). The
HQQ-lite solver runs a few proximal iterations optimizing the zero-point
under an l_p (p<1) sparsity prior on the reconstruction residual —
jnp-only, so it runs inside jit.

Packed storage: two int4 codes per uint8 along the grouped axis.

The dequant-matmul path (:func:`qmatmul`) routes through the fused
Pallas INT4 kernel (kernels/int4_matmul) under the "pallas"/"auto"
backends: quantize the *transposed* weight with :func:`quantize_linear`
so the HQQ groups lie along the contraction axis, then
:func:`matmul_layout` repacks the identical codes into the kernel's
(K//2, N) storage — the reference and kernel paths dequantize the exact
same values.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class QTensor(NamedTuple):
    packed: jax.Array  # uint8 (..., K//2) two nibbles per byte
    scale: jax.Array  # f32 (..., K//group, 1)
    zero: jax.Array  # f32 (..., K//group, 1)
    shape: tuple  # original shape
    group: int


def _shrink_lp(x, beta: float, p: float):
    """Proximal operator of the l_p norm (HQQ eq. 4): soft-threshold with
    |x|^(p-1) reweighting."""
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - (jnp.abs(x) ** (p - 1.0)) / beta, 0.0)


def quantize(w: jax.Array, *, group: int = 64, iters: int = 10, p: float = 0.7,
             beta: float = 10.0) -> QTensor:
    """Quantize along the LAST axis to int4 codes in [0, 15]."""
    orig_shape = w.shape
    K = orig_shape[-1]
    assert K % group == 0 and group % 2 == 0, (K, group)
    wg = w.astype(jnp.float32).reshape(*orig_shape[:-1], K // group, group)
    wmin = wg.min(-1, keepdims=True)
    wmax = wg.max(-1, keepdims=True)
    scale = jnp.maximum((wmax - wmin) / 15.0, 1e-8)
    zero = -wmin / scale

    def step(carry, _):
        zero, beta_t = carry
        q = jnp.clip(jnp.round(wg / scale + zero), 0, 15)
        e = wg - (q - zero) * scale
        e_s = _shrink_lp(e, beta_t, p)
        zero_new = jnp.mean(q - (wg - e_s) / scale, axis=-1, keepdims=True)
        return (zero_new, beta_t * 1.01), None

    (zero, _), _ = jax.lax.scan(step, (zero, jnp.asarray(beta, jnp.float32)),
                                None, length=iters)
    q = jnp.clip(jnp.round(wg / scale + zero), 0, 15).astype(jnp.uint8)
    q = q.reshape(*orig_shape[:-1], K)
    packed = (q[..., 0::2] | (q[..., 1::2] << 4)).astype(jnp.uint8)
    return QTensor(
        packed=packed,
        scale=scale.reshape(*orig_shape[:-1], K // group, 1),
        zero=zero.reshape(*orig_shape[:-1], K // group, 1),
        shape=orig_shape,
        group=group,
    )


def unpack_codes(qt: QTensor) -> jax.Array:
    lo = qt.packed & 0x0F
    hi = qt.packed >> 4
    q = jnp.stack([lo, hi], axis=-1).reshape(*qt.shape[:-1], qt.shape[-1])
    return q


def dequantize(qt: QTensor, dtype=jnp.bfloat16) -> jax.Array:
    q = unpack_codes(qt).astype(jnp.float32)
    K = qt.shape[-1]
    qg = q.reshape(*qt.shape[:-1], K // qt.group, qt.group)
    w = (qg - qt.zero) * qt.scale
    return w.reshape(qt.shape).astype(dtype)


def quant_bytes(qt: QTensor) -> int:
    n = qt.packed.size + 4 * qt.scale.size + 4 * qt.zero.size
    return int(n)


# ---------------------------------------------------------------------------
# Fused dequant-matmul (kernels/int4_matmul wiring)
# ---------------------------------------------------------------------------


def quantize_linear(w: jax.Array, *, group: int = 64, **hqq_kw) -> QTensor:
    """Quantize a matmul weight w (K, N) for ``y = x @ dequant(w)``.

    Stores the HQQ codes of ``w.T`` (N, K) so groups run along the
    contraction axis K — the layout both the reference dequant and the
    fused kernel agree on."""
    assert w.ndim == 2, w.shape
    return quantize(w.T, group=group, **hqq_kw)


def dequantize_linear(ql: QTensor, dtype=jnp.float32) -> jax.Array:
    """QTensor from :func:`quantize_linear` -> the original-layout (K, N)."""
    return dequantize(ql, dtype).T


def matmul_layout(ql: QTensor):
    """Repack a :func:`quantize_linear` QTensor (codes of w.T, (N, K))
    into the kernel storage: packed (K//2, N), scale/zero (K//group, N).
    Bit-exact — the same int4 codes, transposed and repacked."""
    from ..kernels.int4_matmul.ops import MatmulQWeight

    # shape/group may have round-tripped through np.asarray (host stores
    # tree-map whole QTensors) — force back to python ints, they feed
    # static jit args downstream
    N, K = (int(s) for s in ql.shape)
    group = int(ql.group)
    q = unpack_codes(ql).T  # (K, N) int4 codes
    packed = (q[0::2] | (q[1::2] << 4)).astype(jnp.uint8)
    scale = ql.scale.reshape(N, K // group).T  # (K//group, N)
    zero = ql.zero.reshape(N, K // group).T
    return MatmulQWeight(packed, scale.astype(jnp.float32),
                         zero.astype(jnp.float32), group)


def qmatmul(x: jax.Array, ql, *, backend: Optional[str] = None,
            interpret: Optional[bool] = None) -> jax.Array:
    """y = x @ dequant(ql). ``ql``: QTensor from :func:`quantize_linear`
    or a prepacked ``MatmulQWeight`` (precompute via :func:`matmul_layout`
    to repack once per weight, not per call).

    backend "ref" multiplies by the dequantized weight; "pallas"/"auto"
    runs the fused dequant matmul kernel (interpret mode off-TPU)."""
    from ..kernels.dispatch import resolve
    from ..kernels.int4_matmul.ops import MatmulQWeight, int4_matmul

    choice = resolve("int4_matmul", backend or "auto", interpret=interpret)
    if isinstance(ql, QTensor):
        if not choice.use_pallas:
            return x @ dequantize_linear(ql, jnp.float32).astype(x.dtype)
        mq = matmul_layout(ql)
    else:
        mq = ql
    if not choice.use_pallas:
        from ..kernels.int4_matmul.ref import int4_matmul_ref

        lead = x.shape[:-1]
        out = int4_matmul_ref(x.reshape(-1, x.shape[-1]), mq.packed, mq.scale,
                              mq.zero, mq.group)
        return out.reshape(*lead, -1)
    return int4_matmul(x, mq.packed, mq.scale, mq.zero, group=mq.group,
                       backend="pallas", interpret=choice.interpret)


def quant_error(w: jax.Array, qt: QTensor) -> float:
    return float(jnp.abs(w.astype(jnp.float32) - dequantize(qt, jnp.float32)).mean())
