"""Fleet worker: one journaled server under a heartbeat.

    python -m repro.fleet.worker WORKER_DIR/spec.json [--clean]

The supervisor writes ``spec.json`` (serving config) and ``trace.json``
(this worker's request partition, as journal-compatible records) into
the worker directory and launches this module. Every incarnation runs
the same sequence — there is no separate "--resume" mode, recovery is
implicit:

* recover the journal under ``WORKER_DIR/journal`` (a fresh directory
  recovers to nothing),
* merge the trace with the recovered state — the journal's seen-rid
  set dedupes arrivals, so restarts and supervisor re-offers are safe,
* journal every pending arrival *before* the slow model build, so a
  kill during compile still leaves the work assignment durable,
* serve through the standard journaled server run loop, emitting one
  atomic heartbeat per decode step / wave via the ``on_step`` hook,
* poll ``WORKER_DIR/inbox/`` for requests the supervisor re-offers
  from failed peers (journaled as arrivals before the inbox file is
  consumed, so a crash between the two only re-offers, never loses),
* drain gracefully on SIGTERM: stop admission, finish in-flight,
  final anchored checkpoint, ``results.json``, exit 0.

Worker-level faults (``kill=`` / ``hang=`` kinds from the spec; the
supervisor strips them on restart via ``--clean``) fire from the step
hook: a kill is ``os._exit`` mid-serve — no unwinding, the journal is
current through the last completed step — and a hang sleeps silently
so only the supervisor's heartbeat-staleness deadline can notice.
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import sys
import time
from pathlib import Path
from typing import Dict, List, Set

import jax
import jax.numpy as jnp  # noqa: F401  (jax initialized before servers)

from ..configs import get_config
from ..faults import get_fault_plan, install_fault_plan, uninstall_fault_plan
from ..models.model import init_params
from ..recovery import RequestJournal, recover
from ..recovery.checkpoint import record_request
from ..serving import (
    ContinuousBatchingServer,
    OffloadedWaveServer,
    RequestQueue,
    get_scheduler,
)
from ..serving.metrics import ServerMetrics
from .heartbeat import HEARTBEAT_NAME, HeartbeatWriter

# hard-exit status for an injected kill; anything nonzero reads as a
# crash to the supervisor, this value just makes logs unambiguous
KILL_EXIT_CODE = 13


def write_results(path, results: Dict[int, object], mt, *,
                  drained: bool) -> None:
    """Atomic per-worker results artifact (convenience only — the
    journal is the authority; the supervisor aggregates via recover())."""
    payload = {
        "pid": os.getpid(),
        "drained": bool(drained),
        "results": [{"rid": r.rid, "tokens": [int(t) for t in r.tokens],
                     "finish_reason": r.finish_reason}
                    for r in sorted(results.values(), key=lambda r: r.rid)],
        "summary": mt.summary() if mt is not None else {},
    }
    tmp = str(path) + ".tmp"
    with open(tmp, "w", encoding="utf-8") as f:
        json.dump(payload, f, indent=2)
    os.replace(tmp, path)


def poll_inbox(wdir: Path, enqueued: Set[int], queue: RequestQueue,
               jr: RequestJournal) -> int:
    """Consume supervisor re-offers: each inbox file is a JSON list of
    request records. The arrival is journaled (flushed) before the file
    is unlinked — a kill between the two replays as a duplicate offer,
    which the seen-rid dedupe absorbs."""
    inbox = wdir / "inbox"
    if not inbox.is_dir():
        return 0
    n = 0
    for p in sorted(inbox.glob("*.json")):
        try:
            recs = json.loads(p.read_text(encoding="utf-8"))
        except (OSError, json.JSONDecodeError):
            continue  # supervisor writes atomically; transient at worst
        for rec in recs:
            req = record_request(rec)
            if req.rid in enqueued:
                continue
            jr.arrival(req)
            queue.push(req)
            enqueued.add(req.rid)
            n += 1
        p.unlink(missing_ok=True)
    return n


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("spec", help="path to the worker's spec.json")
    ap.add_argument("--clean", action="store_true",
                    help="ignore the spec's fault plan (supervisor "
                         "restarts run clean so a deterministic fault "
                         "doesn't re-fire forever)")
    args = ap.parse_args(argv)

    spec = json.loads(Path(args.spec).read_text(encoding="utf-8"))
    wdir = Path(spec.get("dir") or Path(args.spec).parent)
    hb = HeartbeatWriter(wdir / HEARTBEAT_NAME)
    hb.beat(phase="init")
    hb_s = float(spec.get("heartbeat_s", 0.25))

    drain = {"flag": False}
    signal.signal(signal.SIGTERM,
                  lambda *_: drain.__setitem__("flag", True))

    # fault plan: only what the spec says — a leaked REPRO_FAULTS env
    # var (already auto-installed at import) must not fault a worker
    if args.clean or not spec.get("faults"):
        uninstall_fault_plan()
    else:
        install_fault_plan(spec["faults"])
    plan = get_fault_plan()

    # -- recover + merge the trace (before any slow model work) --------
    trace = [record_request(rec) for rec in json.loads(
        (wdir / "trace.json").read_text(encoding="utf-8"))]
    jdir = wdir / "journal"
    state = recover(jdir)
    seen: Set[int] = set(state.seen_rids) if state else set()
    pending = list(state.pending) if state else []
    pending += [r for r in trace if r.rid not in seen]
    pending.sort(key=lambda r: (r.arrival_time, r.rid))
    enqueued: Set[int] = seen | {r.rid for r in pending}
    results = {r.rid: r for r in (state.results if state else [])}
    mt = state.metrics if state else ServerMetrics(
        policy=spec.get("scheduler", "fcfs"))

    jr = RequestJournal(jdir, seen=set(seen),
                        retain_segments=spec.get("retain_segments", 2))
    for r in pending:
        jr.arrival(r)  # durable before the compile window

    # -- build the server (the slow part: params init + jit warmup) ----
    cfg = get_config(spec["arch"])
    params = init_params(jax.random.key(int(spec.get("param_seed", 0))),
                         cfg, jnp.float32)
    mode = spec.get("mode", "continuous")
    scheduler = get_scheduler(spec.get("scheduler", "fcfs"))
    if mode == "wave":
        srv = OffloadedWaveServer(
            cfg, params,
            capacity=int(spec.get("capacity") or cfg.melinoe_cache_capacity()),
            scheduler=scheduler, wave_size=int(spec.get("slots", 2)),
            overlap=bool(spec.get("overlap", False)),
            engine_impl=spec.get("engine_impl", "slab"),
            seed=int(spec.get("seed", 0)))
        if state is not None and state.engine is not None:
            srv.engine.metrics.load_state(state.engine["metrics"])
            srv.engine.revive(state.engine["cache"], warm=True)
    else:
        max_len = int(spec.get("max_len") or (max(
            (r.prompt_len + r.max_new_tokens for r in (pending or trace)),
            default=32) + 1))
        srv = ContinuousBatchingServer(
            cfg, params, n_slots=int(spec.get("slots", 2)), max_len=max_len,
            scheduler=scheduler, seed=int(spec.get("seed", 0)))
    hb.beat(phase="ready")

    queue = RequestQueue(pending)
    steps = {"total": int(state.step) if state else 0}
    last = {"now": 0.0, "backlog": len(pending), "in_flight": 0}

    def step_hook(info: Dict) -> None:
        # worker-level faults first: the kill must look like SIGKILL
        # (journal flushed through this step, nothing else written)
        if plan.enabled:
            if plan.maybe_kill("fleet.worker.step"):
                os._exit(KILL_EXIT_CODE)
            hang_s = plan.maybe_hang()
            if hang_s > 0.0:
                time.sleep(hang_s)  # wedged: no beat, no progress
        poll_inbox(wdir, enqueued, queue, jr)
        steps["total"] += 1
        last.update(now=info["now"], backlog=info["backlog"],
                    in_flight=info["in_flight"])
        hb.beat(phase="serving", step=steps["total"], now=info["now"],
                backlog=info["backlog"], in_flight=info["in_flight"],
                finished=info["finished"], generated=info["generated"],
                metrics=mt.summary(), min_interval_s=hb_s)

    drained = False
    first_pass = True
    try:
        while True:
            poll_inbox(wdir, enqueued, queue, jr)
            if not len(queue):
                if drain["flag"]:
                    break
                hb.beat(phase="idle", step=steps["total"],
                        now=last["now"], backlog=0, in_flight=0,
                        finished=mt.requests_finished,
                        generated=mt.generated_tokens,
                        min_interval_s=hb_s)
                time.sleep(float(spec.get("poll_s", 0.05)))
                continue
            res, mt = srv.run(
                queue, mt, journal=jr,
                checkpoint_every=int(spec.get("checkpoint_every", 4)),
                audit_every=(int(spec.get("audit_every", 0)) or None
                             if first_pass else None),
                resume=state if first_pass else None,
                on_step=step_hook,
                should_drain=lambda: drain["flag"])
            first_pass = False
            state = None
            for r in res:
                results[r.rid] = r
            write_results(wdir / "results.json", results, mt,
                          drained=getattr(srv, "drained", False))
            if getattr(srv, "drained", False):
                drained = True
                break
    finally:
        jr.close()

    write_results(wdir / "results.json", results, mt, drained=drained)
    hb.beat(phase="drained" if drained else "done", step=steps["total"],
            now=last["now"], backlog=0, in_flight=0,
            finished=mt.requests_finished, generated=mt.generated_tokens,
            metrics=mt.summary())
    return 0


if __name__ == "__main__":
    sys.exit(main())
