"""Fleet supervisor: launch N journaled workers and keep them alive.

The loop ROADMAP item 5 asks for — launch -> health-check -> collect ->
restart-from-journal — over local worker processes:

* **partition**: the request trace is split round-robin in arrival
  order; each worker gets ``worker-i/spec.json`` + ``trace.json`` and
  its own journal directory.
* **classify**: every poll the supervisor reads each worker's atomic
  heartbeat and classifies it healthy / degraded (beat older than the
  soft deadline) / hung (beat older than the hang deadline while the
  process still runs — SIGKILL it and treat as a crash) / dead
  (nonzero exit). Heartbeats carry the writer's pid, so a stale file
  from the previous incarnation never condemns a restarting process;
  phases ``init``/``ready`` get the startup grace instead (model build
  + jit warmup are legitimately silent).
* **restart**: a crashed or hung worker relaunches from its journal
  (recovery is implicit in the worker — PR 9 makes the continuation
  token-identical), under capped exponential backoff with the seeded
  per-worker jitter from ``FetchPolicy`` so a correlated failure does
  not restart the fleet in lockstep. Injected fault specs are stripped
  on restart (``--clean``) so a deterministic ``kill_at`` cannot
  re-fire forever.
* **circuit breaker**: past ``max_restarts`` the worker is marked
  failed and its unfinished journaled requests (recovered pending —
  with watermarks — plus never-journaled trace rids) are re-offered
  round-robin to the survivors' inboxes; the journal's seen-rid set
  makes duplicate offers harmless.
* **drain**: SIGTERM (to the supervisor or via :meth:`request_drain`)
  forwards SIGTERM to every live worker; each stops admission,
  finishes in-flight, anchors a final checkpoint and exits 0.

Telemetry lands on a ``repro.obs`` registry: per-worker heartbeat-age
and up gauges, ``worker_restarts_total{reason}``,
``requests_reassigned_total``, and a failover-time histogram (fault
detected -> first heartbeat of the replacement incarnation).
"""
from __future__ import annotations

import json
import os
import signal
import subprocess
import sys
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional

from ..faults import FetchPolicy, parse_fault_spec
from ..obs.registry import MetricsRegistry
from ..recovery import recover
from ..recovery.checkpoint import request_record
from .heartbeat import HEARTBEAT_NAME, read_heartbeat

# failover includes a fresh process's jax import + jit warmup, so the
# default obs buckets (<=10s) would clip every sample
FAILOVER_BUCKETS = (0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 40.0, 80.0, 160.0)

# capped exponential restart backoff, in wall seconds; jitter_frac
# decorrelates workers that died together (salt = worker index)
RESTART_BACKOFF = FetchPolicy(
    max_retries=-1, backoff_base_s=0.25, backoff_mult=2.0,
    backoff_cap_s=4.0, jitter_frac=0.5, seed=0)


def parse_worker_fault_schedule(spec: Optional[str]) -> Dict[int, str]:
    """``"0:kill_at=6;2:hang_at=4:30,seed=1"`` -> {0: "...", 2: "..."}.
    Each entry is ``<worker_idx>:<REPRO_FAULTS grammar>``; specs are
    validated eagerly so a typo fails the launch, not the chaos run."""
    out: Dict[int, str] = {}
    if not spec:
        return out
    for item in spec.split(";"):
        item = item.strip()
        if not item:
            continue
        idx_s, _, plan = item.partition(":")
        idx = int(idx_s)
        parse_fault_spec(plan)  # raises on unknown keys
        out[idx] = plan
    return out


@dataclass
class FleetConfig:
    n_workers: int = 2
    arch: str = "olmoe-mini"
    mode: str = "continuous"  # "continuous" | "wave"
    slots: int = 2
    capacity: int = 0
    scheduler: str = "fcfs"
    seed: int = 0
    param_seed: int = 0
    overlap: bool = False
    engine_impl: str = "slab"
    checkpoint_every: int = 4
    retain_segments: int = 2
    audit_every: int = 0
    heartbeat_s: float = 0.25  # worker beat throttle
    worker_poll_s: float = 0.05  # worker idle/inbox poll
    poll_s: float = 0.1  # supervisor liveness poll
    degraded_after_s: float = 3.0  # stale-ish: flagged, not yet killed
    hang_deadline_s: float = 10.0  # stale while alive => SIGKILL
    startup_grace_s: float = 300.0  # init/ready phases (imports + jit)
    max_restarts: int = 3  # circuit breaker: beyond => failed
    drain_timeout_s: float = 60.0
    # worker-targeted fault schedule {idx: REPRO_FAULTS spec}, first
    # incarnation only — restarts always run --clean
    worker_faults: Dict[int, str] = field(default_factory=dict)


@dataclass
class WorkerHandle:
    idx: int
    dir: Path
    assigned: List = field(default_factory=list)  # ServeRequest
    proc: Optional[subprocess.Popen] = None
    log_fh: Optional[object] = None
    state: str = "starting"
    phase: str = ""
    restarts: int = 0
    failed: bool = False
    completed: bool = False
    exit_code: Optional[int] = None
    launched_at: float = 0.0
    restart_at: Optional[float] = None  # backoff: relaunch not before
    down_at: Optional[float] = None  # failover clock start
    hb: Optional[Dict] = None  # last heartbeat of the live incarnation

    @property
    def live(self) -> bool:
        return not (self.failed or self.completed)


class FleetSupervisor:
    """Drive a fleet of ``repro.fleet.worker`` processes to completion."""

    def __init__(self, requests, cfg: FleetConfig, root,
                 registry: Optional[MetricsRegistry] = None):
        assert cfg.n_workers >= 1
        self.cfg = cfg
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.requests = sorted(requests,
                               key=lambda r: (r.arrival_time, r.rid))
        self.total_rids = {r.rid for r in self.requests}
        self.registry = registry if registry is not None else MetricsRegistry()
        self.workers: List[WorkerHandle] = []
        self.events: List[Dict] = []
        self.timeline: List[Dict] = []
        self.failover_samples: List[float] = []
        self._drain_requested = False
        self._reassign_seq = 0
        self._t0: Optional[float] = None
        # materialize the counters chaos dashboards alert on, so a
        # clean run still exports them at 0
        for reason in ("crash", "hang"):
            self.registry.counter(
                "worker_restarts_total",
                "fleet worker restarts by failure reason", reason=reason)
        self.registry.counter("requests_reassigned_total",
                              "requests re-offered after a circuit break")
        self.registry.histogram(
            "fleet_failover_s",
            "fault detected -> first heartbeat of the replacement",
            buckets=FAILOVER_BUCKETS)

    # -- setup -----------------------------------------------------------
    def _max_len(self) -> int:
        # one bound for the whole fleet: any request may be re-offered
        # to any worker, so every slot pool must fit the largest
        return max((r.prompt_len + r.max_new_tokens
                    for r in self.requests), default=32) + 1

    def _event(self, worker: int, event: str, **detail) -> None:
        t = 0.0 if self._t0 is None else time.time() - self._t0
        self.events.append({"t": round(t, 3), "worker": worker,
                            "event": event, **detail})

    def setup(self) -> None:
        """Partition the trace and write every worker directory."""
        c = self.cfg
        parts: List[List] = [[] for _ in range(c.n_workers)]
        for i, r in enumerate(self.requests):
            parts[i % c.n_workers].append(r)
        for idx in range(c.n_workers):
            wdir = self.root / f"worker-{idx}"
            (wdir / "inbox").mkdir(parents=True, exist_ok=True)
            w = WorkerHandle(idx=idx, dir=wdir, assigned=list(parts[idx]))
            spec = {
                "dir": str(wdir), "arch": c.arch, "mode": c.mode,
                "slots": c.slots, "capacity": c.capacity,
                "scheduler": c.scheduler, "seed": c.seed,
                "param_seed": c.param_seed, "overlap": c.overlap,
                "engine_impl": c.engine_impl, "max_len": self._max_len(),
                "checkpoint_every": c.checkpoint_every,
                "retain_segments": c.retain_segments,
                "audit_every": c.audit_every,
                "heartbeat_s": c.heartbeat_s, "poll_s": c.worker_poll_s,
                "faults": c.worker_faults.get(idx),
            }
            (wdir / "spec.json").write_text(json.dumps(spec, indent=2),
                                            encoding="utf-8")
            (wdir / "trace.json").write_text(
                json.dumps([request_record(r, binary=False)
                            for r in parts[idx]]), encoding="utf-8")
            self.workers.append(w)

    def _launch(self, w: WorkerHandle, *, clean: bool) -> None:
        env = dict(os.environ)
        env.pop("REPRO_JOURNAL", None)  # per-worker journals only
        env.pop("REPRO_FAULTS", None)  # faults ride in the spec
        src = str(Path(__file__).resolve().parents[2])
        env["PYTHONPATH"] = (src + os.pathsep + env["PYTHONPATH"]
                             if env.get("PYTHONPATH") else src)
        cmd = [sys.executable, "-m", "repro.fleet.worker",
               str(w.dir / "spec.json")]
        if clean:
            cmd.append("--clean")
        if w.log_fh is not None:
            w.log_fh.close()
        w.log_fh = open(w.dir / "worker.log", "ab")
        w.proc = subprocess.Popen(cmd, env=env, stdout=w.log_fh,
                                  stderr=subprocess.STDOUT)
        w.launched_at = time.time()
        w.restart_at = None
        w.state = "starting"
        w.hb = None
        self._event(w.idx, "launch", pid=w.proc.pid, clean=clean,
                    restarts=w.restarts)

    # -- liveness --------------------------------------------------------
    def _on_down(self, w: WorkerHandle, reason: str, now: float) -> None:
        """A live incarnation is gone (crash) or was just killed (hang):
        schedule a restart under backoff, or trip the circuit breaker."""
        self.registry.counter("worker_restarts_total",
                              reason=reason).inc()
        if w.down_at is None:
            w.down_at = now  # failover clock: first detection wins
        w.proc = None
        w.restarts += 1
        self._event(w.idx, reason, restarts=w.restarts)
        if w.restarts > self.cfg.max_restarts:
            self._circuit_break(w)
            return
        delay = RESTART_BACKOFF.backoff(w.restarts - 1, salt=w.idx)
        w.restart_at = now + delay
        w.state = "down"
        self._event(w.idx, "restart_scheduled", delay_s=round(delay, 3))

    def _circuit_break(self, w: WorkerHandle) -> None:
        """Flapping worker: mark failed and re-offer its unfinished
        requests to the survivors. Journal pending (watermarks intact)
        wins over the raw trace record for the same rid."""
        w.failed = True
        w.state = "failed"
        self._event(w.idx, "circuit_break", restarts=w.restarts)
        st = recover(w.dir / "journal")
        seen = st.seen_rids if st else set()
        by_rid = {r.rid: r for r in w.assigned if r.rid not in seen}
        for r in (st.pending if st else []):
            by_rid[r.rid] = r
        unfinished = sorted(by_rid.values(),
                            key=lambda r: (r.arrival_time, r.rid))
        if not unfinished:
            return
        survivors = [v for v in self.workers if v.live]
        if not survivors:
            # everyone else already finished and exited: bring the
            # least-flappy completed worker back (clean) to absorb it
            done = [v for v in self.workers if v.completed]
            assert done, "circuit break with no possible survivor"
            back = min(done, key=lambda v: v.restarts)
            back.completed = False
            self._launch(back, clean=True)
            survivors = [back]
        batches: List[List] = [[] for _ in survivors]
        for i, r in enumerate(unfinished):
            batches[i % len(survivors)].append(r)
        for v, batch in zip(survivors, batches):
            if not batch:
                continue
            self._reassign_seq += 1
            payload = json.dumps([request_record(r, binary=False)
                                  for r in batch])
            tmp = v.dir / "inbox" / f".reassign-{self._reassign_seq:04d}.tmp"
            tmp.write_text(payload, encoding="utf-8")
            os.replace(tmp, v.dir / "inbox"
                       / f"reassign-{self._reassign_seq:04d}.json")
            v.assigned.extend(batch)
            self.registry.counter("requests_reassigned_total").inc(len(batch))
            self._event(v.idx, "reassigned_to", n=len(batch),
                        source=w.idx)

    def poll_once(self, now: Optional[float] = None) -> None:
        now = time.time() if now is None else now
        c = self.cfg
        finished_est = 0
        for w in self.workers:
            if not w.live:
                finished_est += (w.hb or {}).get("finished", 0)
                continue
            if w.proc is None:  # waiting out restart backoff
                if w.restart_at is not None and now >= w.restart_at:
                    self._launch(w, clean=True)
                continue
            rc = w.proc.poll()
            hb = read_heartbeat(w.dir / HEARTBEAT_NAME)
            cur = hb if hb and hb.get("pid") == w.proc.pid else None
            if cur is not None:
                w.hb = cur
                w.phase = cur.get("phase", "")
                if w.down_at is not None and cur.get("phase") not in (
                        "init", "ready"):
                    # replacement incarnation is past startup and
                    # serving/idle again: failover complete
                    dt = now - w.down_at
                    self.failover_samples.append(dt)
                    self.registry.histogram(
                        "fleet_failover_s", buckets=FAILOVER_BUCKETS
                    ).observe(dt)
                    self._event(w.idx, "failover_complete",
                                s=round(dt, 3))
                    w.down_at = None
            finished_est += (w.hb or {}).get("finished", 0)
            if rc is not None:  # process exited
                w.exit_code = rc
                if rc == 0 and w.phase in ("done", "drained"):
                    w.completed = True
                    w.state = "done"
                    self._event(w.idx, "completed", phase=w.phase)
                else:
                    self._on_down(w, "crash", now)
                continue
            # alive: staleness classification
            age = (now - cur["ts"]) if cur is not None \
                else (now - w.launched_at)
            self.registry.gauge("fleet_heartbeat_age_s",
                                "age of the worker's last heartbeat",
                                worker=str(w.idx)).set(age)
            self.registry.gauge("fleet_worker_up",
                                "1 while the worker process is live",
                                worker=str(w.idx)).set(1.0)
            in_startup = cur is None or cur.get("phase") in ("init",
                                                             "ready")
            deadline = c.startup_grace_s if in_startup \
                else c.hang_deadline_s
            if age > deadline:
                # hung: heartbeat stale while the process still runs —
                # only SIGKILL gets its slot back; recovery makes the
                # restart token-identical
                self._event(w.idx, "hang_detected", age_s=round(age, 3))
                w.proc.kill()
                w.proc.wait()
                self._on_down(w, "hang", now)
            elif age > c.degraded_after_s and not in_startup:
                w.state = "degraded"
            else:
                w.state = "healthy"
        for w in self.workers:
            if not w.live:
                self.registry.gauge("fleet_worker_up",
                                    "1 while the worker process is live",
                                    worker=str(w.idx)).set(0.0)
        if self._t0 is not None:
            self.timeline.append({
                "t": round(now - self._t0, 3),
                "finished": finished_est,
                "states": {str(w.idx): w.state for w in self.workers}})

    # -- completion ------------------------------------------------------
    def _finished_rids(self) -> set:
        done = set()
        for w in self.workers:
            st = recover(w.dir / "journal")
            if st is not None:
                done.update(r.rid for r in st.results)
        return done

    def _maybe_complete(self) -> bool:
        """Authoritative completion check, gated on cheap signals: every
        live worker idle-or-done, nothing waiting on a restart, and no
        unconsumed inbox re-offers."""
        for w in self.workers:
            if w.failed:
                continue
            if w.live and (w.proc is None
                           or (w.hb or {}).get("phase")
                           not in ("idle", "done", "drained")):
                return False
            if any((w.dir / "inbox").glob("*.json")):
                return False
        return self.total_rids <= self._finished_rids()

    def request_drain(self) -> None:
        self._drain_requested = True

    def drain(self) -> None:
        """Forward SIGTERM, wait for graceful exits, SIGKILL stragglers."""
        for w in self.workers:
            if w.live and w.proc is not None and w.proc.poll() is None:
                w.proc.send_signal(signal.SIGTERM)
                self._event(w.idx, "sigterm")
        deadline = time.time() + self.cfg.drain_timeout_s
        for w in self.workers:
            if w.proc is None:
                continue
            try:
                w.exit_code = w.proc.wait(
                    timeout=max(deadline - time.time(), 0.1))
            except subprocess.TimeoutExpired:
                w.proc.kill()
                w.exit_code = w.proc.wait()
                self._event(w.idx, "drain_kill")
            hb = read_heartbeat(w.dir / HEARTBEAT_NAME)
            if hb:
                w.phase = hb.get("phase", w.phase)
            if w.live and w.exit_code == 0:
                w.completed = True
                w.state = "done"
            if w.log_fh is not None:
                w.log_fh.close()
                w.log_fh = None

    # -- main loop -------------------------------------------------------
    def run(self, max_wall_s: Optional[float] = None) -> Dict:
        self.setup()
        self._t0 = time.time()
        for w in self.workers:
            self._launch(w, clean=w.idx not in self.cfg.worker_faults)
        drained = False
        try:
            while True:
                now = time.time()
                self.poll_once(now)
                if self._drain_requested:
                    drained = True
                    break
                if all(not w.live for w in self.workers):
                    break
                if self._maybe_complete():
                    break
                if max_wall_s is not None and now - self._t0 > max_wall_s:
                    self._event(-1, "wall_timeout")
                    drained = True
                    break
                time.sleep(self.cfg.poll_s)
        finally:
            self.drain()
        return self.collect(drained=drained)

    # -- aggregation -----------------------------------------------------
    def collect(self, *, drained: bool = False) -> Dict:
        """Authoritative fleet report, rebuilt from the journals (a
        worker's results.json can be a step stale; its journal cannot)."""
        finished: Dict[int, object] = {}
        pending: Dict[int, object] = {}
        for w in self.workers:
            st = recover(w.dir / "journal")
            if st is None:
                continue
            for r in st.results:
                finished.setdefault(r.rid, r)
            for r in st.pending:
                pending.setdefault(r.rid, r)
        pend_rids = {rid for rid in pending if rid not in finished}
        unaccounted = sorted(self.total_rids - set(finished) - pend_rids)
        restarts = {
            reason: self.registry.counter("worker_restarts_total",
                                          reason=reason).value
            for reason in ("crash", "hang")}
        fo = self.failover_samples
        report = {
            "n_requests": len(self.requests),
            "n_workers": self.cfg.n_workers,
            "drained": drained,
            "wall_s": round(time.time() - self._t0, 3) if self._t0 else 0.0,
            "workers": [{
                "idx": w.idx, "restarts": w.restarts,
                "failed": w.failed, "completed": w.completed,
                "exit_code": w.exit_code, "phase": w.phase,
            } for w in self.workers],
            "restarts": restarts,
            "reassigned": self.registry.counter(
                "requests_reassigned_total").value,
            "failover_s": {
                "count": len(fo),
                "mean": round(sum(fo) / len(fo), 3) if fo else None,
                "max": round(max(fo), 3) if fo else None,
                "samples": [round(s, 3) for s in fo]},
            "finished": len(finished),
            "pending_checkpointed": sorted(pend_rids),
            "unaccounted": unaccounted,
            "results": {str(rid): {
                "tokens": [int(t) for t in r.tokens],
                "finish_reason": r.finish_reason}
                for rid, r in sorted(finished.items())},
            "events": self.events,
            "timeline": self.timeline,
        }
        return report

    def prometheus_text(self) -> str:
        """Supervisor registry + the latest per-worker heartbeat metric
        summaries re-exported as ``fleet_worker_*`` gauges."""
        for w in self.workers:
            hb = w.hb or read_heartbeat(w.dir / HEARTBEAT_NAME)
            if not hb:
                continue
            for k, v in (hb.get("metrics") or {}).items():
                if isinstance(v, (int, float)) and v is not None:
                    self.registry.gauge(
                        f"fleet_worker_{k}",
                        "aggregated from worker heartbeat snapshots",
                        worker=str(w.idx)).set(float(v))
        return self.registry.to_prometheus_text()
