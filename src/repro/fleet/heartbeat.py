"""Atomic heartbeat files: the worker -> supervisor liveness channel.

One JSON file per worker, overwritten whole via tmp + ``os.replace``,
so the supervisor never reads a torn write and never needs a lock. The
payload carries everything the liveness loop classifies on: a
monotonic sequence number, the writer's pid (so a stale file from a
dead incarnation is never mistaken for the fresh process), the worker
phase (init / ready / serving / idle / drained / done), the cumulative
step watermark, queue depth, and a ``ServerMetrics`` summary snapshot.

Staleness — ``time.time() - hb["ts"]`` — is the *only* signal that can
catch a hung worker: a wedged process keeps its pid and its exit code,
but stops replacing this file.
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path
from typing import Dict, Optional

HEARTBEAT_NAME = "heartbeat.json"


class HeartbeatWriter:
    """Atomically publish the worker's latest liveness snapshot."""

    def __init__(self, path):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self.seq = 0
        self.last_ts = 0.0

    def beat(self, *, phase: str, step: int = 0, now: float = 0.0,
             backlog: int = 0, in_flight: int = 0, finished: int = 0,
             generated: int = 0, metrics: Optional[Dict] = None,
             min_interval_s: float = 0.0) -> bool:
        """Write one heartbeat; returns False when throttled (a beat
        younger than ``min_interval_s`` already exists — phase changes
        should pass 0 to always publish)."""
        t = time.time()
        if min_interval_s > 0.0 and t - self.last_ts < min_interval_s:
            return False
        self.seq += 1
        self.last_ts = t
        payload = {
            "seq": self.seq, "ts": t, "pid": os.getpid(), "phase": phase,
            "step": int(step), "now": float(now), "backlog": int(backlog),
            "in_flight": int(in_flight), "finished": int(finished),
            "generated": int(generated), "metrics": metrics or {},
        }
        tmp = self.path.with_name(self.path.name + ".tmp")
        with open(tmp, "w", encoding="utf-8") as f:
            json.dump(payload, f)
        os.replace(tmp, self.path)
        return True


def read_heartbeat(path) -> Optional[Dict]:
    """Latest heartbeat, or None when missing/unreadable. A partial
    read can't happen (writes are atomic renames), but a worker that
    died before its first beat leaves no file at all."""
    try:
        return json.loads(Path(path).read_text(encoding="utf-8"))
    except (OSError, json.JSONDecodeError):
        return None
