"""Supervised serving fleet (ROADMAP item 5, local-process half).

Layers:
  heartbeat.py  — atomic per-worker heartbeat files (seq, pid, phase,
                  step watermark, queue depth, metrics snapshot)
  worker.py     — ``python -m repro.fleet.worker``: one journaled
                  server per process; implicit journal recovery, inbox
                  re-offers, step-hook heartbeats + worker faults,
                  SIGTERM drain
  supervisor.py — :class:`FleetSupervisor`: partition the trace,
                  launch N workers, classify healthy/degraded/hung/
                  dead, SIGKILL hangs, restart from the journal under
                  jittered backoff, circuit-break flapping workers and
                  re-offer their unfinished requests, drain on
                  SIGTERM, aggregate journals + telemetry
"""
from .heartbeat import HEARTBEAT_NAME, HeartbeatWriter, read_heartbeat
from .supervisor import (
    FleetConfig,
    FleetSupervisor,
    WorkerHandle,
    parse_worker_fault_schedule,
)
from .worker import KILL_EXIT_CODE

__all__ = [
    "HEARTBEAT_NAME",
    "HeartbeatWriter",
    "read_heartbeat",
    "FleetConfig",
    "FleetSupervisor",
    "WorkerHandle",
    "parse_worker_fault_schedule",
    "KILL_EXIT_CODE",
]
