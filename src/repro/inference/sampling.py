"""Token sampling: greedy (paper Table 10) + temperature/top-k/top-p."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits: jax.Array) -> jax.Array:
    """logits (B, 1, V) -> (B, 1) int32."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample_per_row(logits: jax.Array, key, temperatures, *, keys=None) -> jax.Array:
    """Per-row temperature sampling for heterogeneous batches.

    logits (B, 1, V); temperatures (B,) — rows with temperature <= 0 are
    decoded greedily, the rest sampled at their own temperature with
    independent per-row keys (``key`` split B ways, or explicit ``keys``
    (B,) so callers can tie randomness to request identity rather than
    slot index). Returns (B, 1) int32.
    """
    t = jnp.asarray(temperatures, jnp.float32)
    safe = jnp.where(t > 0, t, 1.0)
    if keys is None:
        keys = jax.random.split(key, t.shape[0])
    scaled = logits.astype(jnp.float32) / safe[:, None, None]
    drawn = jax.vmap(lambda k, l: jax.random.categorical(k, l, axis=-1))(keys, scaled)
    return jnp.where((t > 0)[:, None], drawn.astype(jnp.int32), greedy(logits))


def sample(logits: jax.Array, key, *, temperature: float = 1.0,
           top_k: int = 0, top_p: float = 0.0) -> jax.Array:
    if temperature <= 0.0:
        return greedy(logits)
    l = logits.astype(jnp.float32) / temperature
    if top_k:
        kth = jnp.sort(l, axis=-1)[..., -top_k][..., None]
        l = jnp.where(l < kth, -1e30, l)
    if top_p:
        sorted_l = jnp.sort(l, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(sorted_l, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(cum < top_p, axis=-1, keepdims=True)
        cutoff = jnp.take_along_axis(sorted_l, cutoff_idx, axis=-1)
        l = jnp.where(l < cutoff, -1e30, l)
    return jax.random.categorical(key, l, axis=-1).astype(jnp.int32)
