"""Batched serving engine: jitted prefill + decode over the full model
(fits-in-memory path), with request padding/batching and optional
MELINOE router-probe collection (used to build predictor datasets).

The memory-constrained path is core/offload_engine.OffloadedMoEEngine;
this engine is the throughput path for models that fit, and the
substrate for generating routing traces.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models.model import decode_step, init_cache, prefill
from ..models.runtime import Runtime
from .sampling import greedy, sample_per_row


@dataclass
class Request:
    prompt: np.ndarray  # (T,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    stop_tokens: tuple = ()  # token ids that terminate the completion


@dataclass
class Completion:
    tokens: np.ndarray
    router_probs: Optional[np.ndarray] = None  # (L, T_gen, E)
    finish_reason: str = "length"  # "stop" | "length"


def truncate_at_stop(tokens: np.ndarray, stop_tokens) -> tuple:
    """Cut ``tokens`` at the first stop token (inclusive). Returns
    (tokens, finish_reason)."""
    toks = np.asarray(tokens)
    if stop_tokens:
        hit = np.isin(toks, list(stop_tokens))
        if hit.any():
            return toks[: int(np.argmax(hit)) + 1], "stop"
    return toks, "length"


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, rt: Optional[Runtime] = None,
                 lora=None, lora_scale: float = 1.0, max_batch: int = 8,
                 window_override: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.rt = rt or Runtime(zero_drop=True)
        self.lora = lora
        self.lora_scale = lora_scale
        self.max_batch = max_batch
        self.window_override = window_override
        self._decode_jit = jax.jit(self._decode_fn, static_argnames=("collect",))

    def _decode_fn(self, params, tokens, cache, collect: bool = False):
        logits, new_cache, aux = decode_step(
            params, self.cfg, tokens, cache, self.rt,
            window_override=self.window_override,
            collect_probs=collect, lora=self.lora, lora_scale=self.lora_scale,
        )
        return logits, new_cache, aux

    def generate_batch(self, requests: Sequence[Request], *,
                       collect_probs: bool = False, seed: int = 0) -> List[Completion]:
        """Static batching: left-pad prompts to a common length, prefill
        once, decode to the max requested length."""
        assert len(requests) <= self.max_batch
        B = len(requests)
        lens = [len(r.prompt) for r in requests]
        T = max(lens)
        toks = np.zeros((B, T), np.int32)
        for i, r in enumerate(requests):
            toks[i, T - lens[i]:] = r.prompt  # left padding
        max_new = max(r.max_new_tokens for r in requests)
        n_slots = T + max_new

        logits, cache = prefill(
            self.params, self.cfg, jnp.asarray(toks), self.rt,
            n_slots=n_slots, window_override=self.window_override,
            lora=self.lora, lora_scale=self.lora_scale,
        )
        key = jax.random.key(seed)
        temps = np.asarray([r.temperature for r in requests], np.float32)
        any_sampled = bool(np.any(temps > 0))
        outs = []
        probs_steps = []
        cur = greedy(logits)
        for step in range(max_new):
            outs.append(np.asarray(cur))
            if step == max_new - 1:
                break
            logits, cache, aux = self._decode_jit(
                self.params, cur, cache, collect=collect_probs
            )
            if collect_probs:
                # aux["probs"]: list of (R, B, 1, E) -> (B, L, E)
                p = jnp.concatenate([a[:, :, 0] for a in aux["probs"]], axis=0)
                probs_steps.append(np.asarray(p.transpose(1, 0, 2)))
            if any_sampled:
                key, sk = jax.random.split(key)
                cur = sample_per_row(logits, sk, temps)
            else:
                cur = greedy(logits)
        gen = np.stack(outs, axis=1)[:, :, 0]  # (B, max_new)
        completions = []
        for i, r in enumerate(requests):
            rp = None
            if collect_probs and probs_steps:
                rp = np.stack([p[i] for p in probs_steps], axis=1)  # (L, T_gen, E)
            toks, reason = truncate_at_stop(gen[i, : r.max_new_tokens], r.stop_tokens)
            completions.append(
                Completion(tokens=toks, router_probs=rp, finish_reason=reason)
            )
        return completions


def routing_trace(cfg: ModelConfig, params, prompts: np.ndarray, *, max_new: int = 32,
                  rt: Optional[Runtime] = None, lora=None, lora_scale: float = 1.0):
    """Greedy-decode every prompt, returning (tokens, probs (B, L, T_gen, E)) —
    the dataset generator for the activation predictor (Sec 3.1.2) and the
    transfer-count benchmarks."""
    eng = ServingEngine(cfg, params, rt=rt, lora=lora, lora_scale=lora_scale,
                        max_batch=len(prompts))
    reqs = [Request(prompt=p, max_new_tokens=max_new) for p in prompts]
    comps = eng.generate_batch(reqs, collect_probs=True)
    toks = np.stack([c.tokens for c in comps])
    probs = np.stack([c.router_probs for c in comps])  # (B, L, T_gen-1, E)
    return toks, probs
