"""Kernel microbenchmark: per-op ref-vs-pallas timing -> JSON report.

    PYTHONPATH=src python benchmarks/kernel_bench.py [--quick] \
        [--backend auto] [--out experiments/kernel_bench.json]

Times each kernel family (flash_attn, moe_gmm, int4_matmul, ssd_scan)
against its pure-jnp reference on the current platform. On TPU the
Pallas side runs compiled (the number that matters); on CPU it runs in
interpret mode — those timings are NOT a speed claim, but they pin the
dispatch plumbing and make kernel regressions (lowering failures, shape
fallbacks, parity drift) visible in the bench trajectory. Each entry
records max |ref - pallas| so the report doubles as a parity check.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

ROOT = Path(__file__).resolve().parents[1]


def _time(fn, *args, iters: int, warmup: int = 2) -> float:
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e3  # ms


def bench_ops(quick: bool, backend: str) -> list:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import dispatch
    from repro.kernels.flash_attn import ops as fa_ops
    from repro.kernels.int4_matmul import ops as i4_ops
    from repro.kernels.int4_matmul.ref import int4_matmul_ref
    from repro.kernels.moe_gmm import ops as gmm_ops
    from repro.kernels.moe_gmm.ref import gmm_ref
    from repro.kernels.ssd_scan import ops as ssd_ops
    from repro.kernels.ssd_scan.ref import ssd_scan_ref

    interpret = dispatch.resolve("moe_gmm", backend).interpret
    iters = 3 if interpret else 20
    s = 1 if quick or interpret else 4  # scale factor

    entries = []

    def record(op, shapes, ref_fn, pallas_fn, ref_out, pal_out):
        ref_ms = _time(ref_fn, iters=iters)
        pal_ms = _time(pallas_fn, iters=iters)
        diff = float(jnp.max(jnp.abs(
            jnp.asarray(ref_out, jnp.float32) - jnp.asarray(pal_out, jnp.float32)
        )))
        entries.append({
            "op": op, "shapes": shapes, "ref_ms": round(ref_ms, 4),
            "pallas_ms": round(pal_ms, 4),
            "speedup": round(ref_ms / max(pal_ms, 1e-9), 3),
            "max_abs_diff": diff,
        })
        print(f"{op:12s} ref={ref_ms:9.3f}ms pallas={pal_ms:9.3f}ms "
              f"x{ref_ms / max(pal_ms, 1e-9):6.2f}  |diff|={diff:.2e}", flush=True)

    # flash_attn: prefill-shaped causal GQA
    B, T, Hkv, G, hd = 1, 128 * s, 2, 2, 64
    q = jax.random.normal(jax.random.key(0), (B, T, Hkv, G, hd), jnp.float32)
    k = jax.random.normal(jax.random.key(1), (B, T, Hkv, hd), jnp.float32)
    v = jax.random.normal(jax.random.key(2), (B, T, Hkv, hd), jnp.float32)
    ref = jax.jit(lambda: fa_ops.attention_ref(q, k, v))
    pal = jax.jit(lambda: fa_ops.flash(q, k, v, backend="pallas",
                                       interpret=interpret))
    record("flash_attn", {"B": B, "T": T, "Hkv": Hkv, "G": G, "hd": hd},
           ref, pal, ref(), pal())

    # moe_gmm: grouped expert FFN matmul
    E, M, K, N = 8, 64 * s, 128, 256
    a = jax.random.normal(jax.random.key(3), (E, M, K), jnp.float32)
    b = jax.random.normal(jax.random.key(4), (E, K, N), jnp.float32)
    ref = jax.jit(lambda: gmm_ref(a, b))
    pal = jax.jit(lambda: gmm_ops.gmm(a, b, backend="pallas",
                                      interpret=interpret))
    record("moe_gmm", {"E": E, "M": M, "K": K, "N": N}, ref, pal, ref(), pal())

    # int4_matmul: fused dequant matmul
    M, K, N, group = 64 * s, 512, 256, 64
    x = jax.random.normal(jax.random.key(5), (M, K), jnp.float32)
    w = jax.random.normal(jax.random.key(6), (K, N)) * 0.05
    qw = i4_ops.quantize_matmul_weight(w, group)
    ref = jax.jit(lambda: int4_matmul_ref(x, qw.packed, qw.scale, qw.zero, group))
    pal = jax.jit(lambda: i4_ops.int4_matmul(
        x, qw.packed, qw.scale, qw.zero, group=group, backend="pallas",
        interpret=interpret))
    record("int4_matmul", {"M": M, "K": K, "N": N, "group": group},
           ref, pal, ref(), pal())

    # ssd_scan: Mamba2 chunked scan
    B, T, H, P, N = 1, 128 * s, 4, 32, 16
    xs = jax.random.normal(jax.random.key(7), (B, T, H, P)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(jax.random.key(8), (B, T, H)))
    A = -jnp.exp(jax.random.normal(jax.random.key(9), (H,)) * 0.3)
    Bm = jax.random.normal(jax.random.key(10), (B, T, N)) * 0.5
    Cm = jax.random.normal(jax.random.key(11), (B, T, N)) * 0.5
    ref = jax.jit(lambda: ssd_scan_ref(xs, dt, A, Bm, Cm)[0])
    pal = jax.jit(lambda: ssd_ops.ssd(xs, dt, A, Bm, Cm, chunk=32,
                                      backend="pallas", interpret=interpret)[0])
    record("ssd_scan", {"B": B, "T": T, "H": H, "P": P, "N": N},
           ref, pal, ref(), pal())

    return entries


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="smaller shapes")
    ap.add_argument("--backend", default="auto", choices=("auto", "pallas"),
                    help="dispatch spec for the pallas side")
    ap.add_argument("--out", default=str(ROOT / "experiments" / "kernel_bench.json"))
    args = ap.parse_args()

    import jax

    from repro.kernels import dispatch

    platform = dispatch.default_platform()
    interpret = dispatch.interpret_default(platform)
    print(f"# kernel_bench: platform={platform} interpret={interpret} "
          f"backend={args.backend}", flush=True)
    entries = bench_ops(args.quick, args.backend)

    report = {
        "platform": platform,
        "interpret": interpret,
        "backend": args.backend,
        "jax_version": jax.__version__,
        "ops": entries,
        "parity_ok": all(e["max_abs_diff"] < 1e-2 for e in entries),
    }
    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(report, indent=2))
    print(f"wrote {out}")
    if not report["parity_ok"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
