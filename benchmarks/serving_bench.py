"""Serving benchmark: scheduling policy × arrival rate × cache capacity
sweep over the offloaded engine, plus the continuous-vs-static decode
comparison on the fits-in-memory path.

    PYTHONPATH=src python benchmarks/serving_bench.py [--quick] \
        [--random-init] [--out experiments/serving_bench.json]

By default the MELINOE fine-tuned olmoe-mini from the shared benchmark
pipeline is served (cached under experiments/bench_cache); --random-init
skips training for a pure plumbing demo. The JSON report contains, per
(rate, capacity) cell, the fcfs / sjf / expert-affinity summaries and
the acceptance checks: identical tokens per request across policies,
and expert-affinity >= fcfs on cache hit rate and Eq.-3 modeled
throughput at equal capacity.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

ROOT = Path(__file__).resolve().parents[1]


def serve_offloaded(cfg, params, requests, *, policy, capacity, wave_size,
                    use_prefetch=True):
    from repro.serving import (OffloadedWaveServer, RequestQueue, get_scheduler)

    kw = {"top_c": capacity} if policy == "expert-affinity" else {}
    srv = OffloadedWaveServer(
        cfg, params, capacity=capacity, scheduler=get_scheduler(policy, **kw),
        wave_size=wave_size, use_prefetch=use_prefetch,
    )
    return srv.run(RequestQueue(requests))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer training steps (default; --full overrides)")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--random-init", action="store_true",
                    help="skip fine-tuning; serve random weights (plumbing demo)")
    ap.add_argument("--n-requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--wave-size", type=int, default=4)
    ap.add_argument("--rates", type=float, nargs="+", default=[2.0, 1e9],
                    help="arrival rates (req/s); 1e9 ~ closed-loop saturation")
    ap.add_argument("--capacities", type=int, nargs="+", default=None,
                    help="cache capacities to sweep (default: E/8, E/4)")
    ap.add_argument("--policies", nargs="+",
                    default=["fcfs", "sjf", "expert-affinity"])
    ap.add_argument("--out", default=str(ROOT / "experiments" / "serving_bench.json"))
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.data.synthetic import ClusterLM, SyntheticConfig
    from repro.models.model import init_params
    from repro.serving import (ContinuousBatchingServer, RequestQueue,
                               TrafficConfig, prefill_expert_scores,
                               serve_static, synthesize_workload)

    if args.random_init:
        cfg = get_config("olmoe-mini")
        params = init_params(jax.random.key(0), cfg, jnp.float32)
        lm = ClusterLM(SyntheticConfig(vocab=cfg.vocab, seq_len=64, seed=0))
        model = "olmoe-mini (random init)"
    else:
        from benchmarks.common import get_pipeline

        pipe = get_pipeline(quick=not args.full)
        cfg, params, lm = pipe.cfg, pipe.ft_params, pipe.lm
        model = "olmoe-mini (MELINOE fine-tuned)"

    E = cfg.moe_spec.num_experts
    capacities = args.capacities or sorted({max(E // 8, 1), max(E // 4, 1)})
    print(f"# serving_bench: {model}, E={E}, capacities={capacities}, "
          f"rates={args.rates}, policies={args.policies}", flush=True)

    report = {"model": model, "arch": cfg.name, "num_experts": E,
              "wave_size": args.wave_size, "n_requests": args.n_requests,
              "sweep": [], "criteria": {}}

    _workloads = {}

    def workload(rate, seed=11):
        # the oracle profiles cost one forward pass per request — score
        # each (rate, seed) trace once and share it across policy runs
        # (servers never mutate requests, only their own queue)
        if (rate, seed) not in _workloads:
            arrival = "all_at_once" if rate >= 1e9 else "poisson"
            tcfg = TrafficConfig(
                n_requests=args.n_requests, arrival=arrival, rate=rate,
                prompt_len=(args.prompt_len // 2, args.prompt_len),
                max_new_tokens=(max(args.max_new // 2, 1), args.max_new),
                seed=seed,
            )
            reqs = synthesize_workload(lm, tcfg)
            prefill_expert_scores(cfg, params, reqs)
            _workloads[(rate, seed)] = reqs
        return _workloads[(rate, seed)]

    ok_tokens, ok_hit, ok_tput = True, True, True
    for rate in args.rates:
        for cap in capacities:
            cell = {"rate": rate, "capacity": cap, "policies": {}}
            tokens = {}
            for pol in args.policies:
                res, mt = serve_offloaded(
                    cfg, params, workload(rate), policy=pol, capacity=cap,
                    wave_size=args.wave_size,
                )
                cell["policies"][pol] = mt.summary()
                tokens[pol] = {r.rid: r.tokens.tolist() for r in res}
                print(f"rate={rate:g} C={cap} {pol:16s} "
                      f"hit={mt.hit_rate:.3f} transfers={mt.transfers} "
                      f"tput={mt.throughput_tok_s():.1f} tok/s "
                      f"p95={mt.latency_percentile(95):.4f}s", flush=True)
            base = tokens[args.policies[0]]
            same = all(tokens[p] == base for p in args.policies)
            cell["tokens_identical"] = same
            ok_tokens &= same
            if "fcfs" in cell["policies"] and "expert-affinity" in cell["policies"]:
                f = cell["policies"]["fcfs"]
                a = cell["policies"]["expert-affinity"]
                cell["affinity_ge_fcfs_hit_rate"] = (
                    a["cache_hit_rate"] >= f["cache_hit_rate"])
                cell["affinity_ge_fcfs_throughput"] = (
                    a["throughput_tok_s"] >= f["throughput_tok_s"])
                ok_hit &= cell["affinity_ge_fcfs_hit_rate"]
                ok_tput &= cell["affinity_ge_fcfs_throughput"]
            report["sweep"].append(cell)

    # ---- fits-in-memory path: continuous vs static batching ------------
    # strongly mixed decode budgets (1x..4x) are where retirement pays;
    # uniform prompt lengths so static left-padding is a no-op and the
    # outputs stay comparable
    tcfg = TrafficConfig(
        n_requests=args.n_requests, arrival="all_at_once",
        prompt_len=(args.prompt_len // 2, args.prompt_len // 2),
        max_new_tokens=(max(args.max_new // 2, 2), args.max_new * 2), seed=23,
    )
    reqs = synthesize_workload(lm, tcfg)
    srv = ContinuousBatchingServer(
        cfg, params, n_slots=args.wave_size,
        max_len=args.prompt_len // 2 + args.max_new * 2 + 1,
    )
    cres, cmt = srv.run(RequestQueue(reqs))
    sres, static_iters = serve_static(cfg, params, reqs, batch_size=args.wave_size)
    cont_static_same = all(
        np.array_equal(a.tokens, b.tokens) for a, b in zip(cres, sres)
    )
    report["continuous_vs_static"] = {
        "continuous_decode_steps": cmt.decode_steps,
        "static_decode_steps": static_iters,
        "tokens_identical": cont_static_same,
        "continuous_wins": cmt.decode_steps < static_iters,
        "slot_occupancy": cmt.occupancy,
        "throughput_tok_s": cmt.throughput_tok_s(),
    }
    print(f"continuous={cmt.decode_steps} static={static_iters} decode steps "
          f"(identical tokens: {cont_static_same})", flush=True)

    report["criteria"] = {
        "tokens_identical_across_policies": ok_tokens,
        "affinity_ge_fcfs_hit_rate": ok_hit,
        "affinity_ge_fcfs_modeled_throughput": ok_tput,
        "continuous_beats_static": report["continuous_vs_static"]["continuous_wins"]
        and cont_static_same,
    }
    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(report, indent=2))
    print(f"wrote {out}")
    print("criteria:", json.dumps(report["criteria"]))
    # the affinity margins come from fine-tuned routing concentration —
    # a random-init model has none (the paper's point), so the plumbing
    # demo reports criteria without enforcing them
    if not args.random_init and not all(report["criteria"].values()):
        sys.exit(1)


if __name__ == "__main__":
    main()
