"""Chaos benchmark: goodput under injected faults, resilient vs naive.

    PYTHONPATH=src python benchmarks/chaos_bench.py [--quick] \
        [--out experiments/BENCH_chaos.json]

Sweeps fault intensity (transient fetch-failure rate, with proportional
transfer spikes and eviction storms) over the offloaded wave server and
compares two configurations under the SAME deterministic fault plan:

  resilient — little-expert degraded mode + bounded retry/backoff +
              per-request SLO + bounded queue (load shedding);
  naive     — no little bank, unbounded zero-backoff retries (every
              fetch eventually succeeds, charging the full stall), no
              admission control.

Reported per intensity: SLO attainment (goodput), goodput in attained
requests per modeled second, tail latency, degradation/shed/retry
counters. The acceptance criteria baked into the report:

  * at zero fault intensity the two configurations produce bit-for-bit
    identical tokens (the little bank is pure capability, zero cost);
  * every admitted request completes under faults (no crashes — shed
    requests are explicit "shed" results, not exceptions);
  * at the 10% fetch-failure plan the resilient configuration's SLO
    attainment is >= 2x the naive baseline's.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

ROOT = Path(__file__).resolve().parents[1]

COUNTER_KEYS = ("requests_shed", "requests_expired", "deadline_retired",
                "slo_attained", "slo_attainment", "degraded_requests",
                "latency_p95", "latency_p99", "goodput_req_s")


def fault_spec(fail: float, seed: int) -> str:
    """One knob scales the whole plan: spikes at the failure rate,
    storms at a quarter of it, magnitudes fixed."""
    if fail <= 0.0:
        return ""
    return (f"fail={fail},spike={fail}:2e-3,"
            f"storm={fail / 4}:0.5,seed={seed}")


def clone_requests(reqs, *, slo, quality):
    """Fresh ServeRequest objects (servers consume queues, fault plans
    mutate arrival times) sharing the prompt/score arrays, with the
    run's SLO and quality dial applied."""
    from repro.serving import ServeRequest

    return [
        ServeRequest(
            rid=r.rid, prompt=r.prompt, max_new_tokens=r.max_new_tokens,
            temperature=r.temperature, stop_tokens=r.stop_tokens,
            arrival_time=r.arrival_time, cluster=r.cluster,
            expert_scores=r.expert_scores, slo=slo, quality=quality,
        )
        for r in reqs
    ]


def serve(cfg, params, reqs, *, capacity, wave_size, spec, resilient,
          max_backlog):
    from repro.faults import (NAIVE_POLICY, FetchPolicy, get_fault_plan,
                              install_fault_plan, uninstall_fault_plan)
    from repro.serving import OffloadedWaveServer, RequestQueue

    if spec:
        install_fault_plan(spec)
    else:
        uninstall_fault_plan()
    try:
        get_fault_plan().compress_arrivals(reqs)
        srv = OffloadedWaveServer(
            cfg, params, capacity=capacity, wave_size=wave_size,
            little_experts=resilient,
            # resilient: degrade after one failed retry instead of
            # stalling; naive: unbounded zero-backoff retries
            fetch_policy=(FetchPolicy(max_retries=1) if resilient
                          else NAIVE_POLICY),
            pressure_frac=0.5,
            max_backlog=max_backlog if resilient else None,
        )
        res, mt = srv.run(RequestQueue(reqs))
        em = srv.engine.metrics
        counters = {
            "fetch_retries": em.fetch_retries,
            "fetch_failures": em.fetch_failures,
            "degraded_uses": em.degraded_uses,
            "fault_delay_s": em.fault_delay_s,
            "transfers": em.transfers,
        }
    finally:
        uninstall_fault_plan()
    return res, mt, counters


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmoe-mini")
    ap.add_argument("--quick", action="store_true",
                    help="smaller workload (CI smoke scale)")
    ap.add_argument("--n-requests", type=int, default=None)
    ap.add_argument("--wave-size", type=int, default=4)
    ap.add_argument("--capacity", type=int, default=0, help="0 => E/4")
    ap.add_argument("--fail-rates", type=float, nargs="+",
                    default=[0.0, 0.05, 0.1, 0.2])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=str(ROOT / "experiments" / "BENCH_chaos.json"))
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.data.synthetic import ClusterLM, SyntheticConfig
    from repro.models.model import init_params
    from repro.serving import (TrafficConfig, prefill_expert_scores,
                               synthesize_workload)

    n_req = args.n_requests or (8 if args.quick else 16)
    cfg = get_config(args.arch)
    params = init_params(jax.random.key(args.seed), cfg, jnp.float32)
    capacity = args.capacity or cfg.melinoe_cache_capacity()
    lm = ClusterLM(SyntheticConfig(vocab=cfg.vocab, seq_len=48,
                                   seed=args.seed))
    tcfg = TrafficConfig(
        n_requests=n_req, arrival="poisson", rate=8.0,
        prompt_len=(8, 16), max_new_tokens=(4, 12), seed=args.seed + 1,
    )
    base_reqs = synthesize_workload(lm, tcfg)
    prefill_expert_scores(cfg, params, base_reqs)
    max_backlog = max(2 * args.wave_size, n_req // 2)

    # -- calibrate the default SLO on a fault-free resilient run ---------
    res0, mt0, _ = serve(
        cfg, params, clone_requests(base_reqs, slo=None, quality=1.0),
        capacity=capacity, wave_size=args.wave_size, spec="",
        resilient=True, max_backlog=None,
    )
    slo = 2.0 * mt0.latency_percentile(95)
    print(f"# chaos_bench: {cfg.name} E={cfg.moe_spec.num_experts} "
          f"C={capacity} n={n_req}  calibrated SLO={slo:.4f}s "
          f"(2 x fault-free p95)", flush=True)

    report = {
        "arch": cfg.name,
        "num_experts": cfg.moe_spec.num_experts,
        "capacity": capacity,
        "n_requests": n_req,
        "wave_size": args.wave_size,
        "max_backlog": max_backlog,
        "slo_s": slo,
        "fault_seed": args.seed + 7,
        "sweep": [],
        "criteria": {},
    }

    ok_complete, parity = True, None
    att = {}
    for fail in args.fail_rates:
        spec = fault_spec(fail, args.seed + 7)
        cell = {"fail_rate": fail, "spec": spec, "configs": {}}
        tokens = {}
        for name, resilient in (("resilient", True), ("naive", False)):
            # the naive baseline predates the SLO machinery: its server
            # never sheds or deadline-stops (slo=None requests); its
            # attainment is judged post hoc against the same yardstick
            res, mt, eng = serve(
                cfg, params,
                clone_requests(base_reqs, slo=slo if resilient else None,
                               quality=1.0),
                capacity=capacity, wave_size=args.wave_size, spec=spec,
                resilient=resilient, max_backlog=max_backlog,
            )
            attained = sum(
                1 for r in res if r.finish_reason in ("stop", "length")
                and r.finish_time - r.arrival_time <= slo
            )
            s = mt.summary()
            cell["configs"][name] = {
                **{k: s[k] for k in COUNTER_KEYS}, **eng,
                "modeled_time_s": s["modeled_time_s"],
                "requests_finished": mt.requests_finished,
                "attained": attained,
                "attainment": attained / n_req,
                "goodput_req_s": attained / max(mt.modeled_time, 1e-12),
            }
            tokens[name] = {r.rid: r.tokens.tolist() for r in res
                            if r.finish_reason != "shed"}
            # every offered request yields exactly one result, crash-free
            ok_complete &= len(res) == n_req
            print(f"fail={fail:<5g} {name:10s} attained={attained}/{n_req} "
                  f"shed={mt.requests_shed}+{mt.requests_expired} "
                  f"deadline={mt.deadline_retired} "
                  f"degraded={mt.degraded_requests} p95="
                  f"{mt.latency_percentile(95):.4f}s", flush=True)
        if fail == 0.0:
            parity = tokens["resilient"] == tokens["naive"]
            cell["tokens_identical"] = parity
        att[fail] = (cell["configs"]["resilient"]["attainment"],
                     cell["configs"]["naive"]["attainment"])
        report["sweep"].append(cell)

    r10, n10 = att.get(0.1, att[max(att)])
    report["criteria"] = {
        "all_requests_resolved": ok_complete,
        "tokens_identical_at_zero_faults": bool(parity),
        "resilient_2x_naive_goodput_at_10pct": bool(
            r10 >= 2.0 * n10 if n10 > 0 else r10 > 0.0),
    }
    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(report, indent=2))
    print(f"wrote {out}")
    print("criteria:", json.dumps(report["criteria"]))
    if not all(report["criteria"].values()):
        sys.exit(1)


if __name__ == "__main__":
    main()
