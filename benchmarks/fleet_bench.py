"""Fleet supervision benchmark: kill/hang sweep over a worker fleet.

    PYTHONPATH=src python benchmarks/fleet_bench.py [--quick] \
        [--out experiments/BENCH_fleet.json]

Serves one Poisson trace four ways (quick: three, two workers):

  fault_free — the fleet baseline: no injected faults, no restarts;
  kill       — per-step ``kill=`` rate faults (>= 10%) on half the
               workers: ``os._exit`` mid-step, journal current through
               the last completed step, supervisor restarts from the
               journal;
  hang       — ``hang_at=`` / ``hang=`` faults: the worker sleeps
               silently while its process stays alive, so only the
               supervisor's heartbeat-staleness deadline can catch it
               (SIGKILL + restart — the path a plain waitpid loop
               cannot see);
  mixed      — kills and hangs in the same run.

Every trial is checked against an uninterrupted in-process
single-server reference over the same trace. Acceptance criteria baked
into the report:

  * zero lost requests: every rid is finished (nothing left pending,
    nothing unaccounted) in every trial;
  * token-identical: each trial's per-request tokens equal the
    reference's — greedy decode depends only on the token prefix and
    the params, so failover across incarnations and workers is exact;
  * every faulted trial actually restarted (crash restarts for kills,
    hang restarts for hangs) and recorded failover-time samples;
  * goodput recovers: after every detected failure, additional
    requests finish (from the supervisor's timeline of
    heartbeat-reported finished counts).
"""
from __future__ import annotations

import argparse
import json
import shutil
import sys
import tempfile
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

ROOT = Path(__file__).resolve().parents[1]


def build_workload(cfg, n_req, seed, rate):
    from repro.data.synthetic import ClusterLM, SyntheticConfig
    from repro.serving import TrafficConfig, synthesize_workload

    lm = ClusterLM(SyntheticConfig(vocab=cfg.vocab, seq_len=32, seed=seed))
    tcfg = TrafficConfig(
        n_requests=n_req, arrival="poisson", rate=rate,
        prompt_len=(6, 12), max_new_tokens=(4, 10),
        temperature=0.0, seed=seed + 1,
    )
    return synthesize_workload(lm, tcfg)


def clone_requests(reqs):
    from repro.serving import ServeRequest

    return [
        ServeRequest(
            rid=r.rid, prompt=r.prompt, max_new_tokens=r.max_new_tokens,
            temperature=r.temperature, stop_tokens=r.stop_tokens,
            arrival_time=r.arrival_time, cluster=r.cluster,
            expert_scores=r.expert_scores,
        )
        for r in reqs
    ]


def reference_tokens(cfg, params, base, slots):
    """Uninterrupted single-server run over the whole trace."""
    from repro.serving import ContinuousBatchingServer, RequestQueue

    max_len = max(r.prompt_len + r.max_new_tokens for r in base) + 1
    srv = ContinuousBatchingServer(cfg, params, n_slots=slots,
                                   max_len=max_len)
    results, mt = srv.run(RequestQueue(clone_requests(base)))
    return ({str(r.rid): [int(t) for t in r.tokens] for r in results}, mt)


def goodput_recovered(report) -> bool:
    """After every detected failure, the fleet finishes more requests.

    ``timeline`` holds the supervisor's per-poll sum of
    heartbeat-reported finished counts; ``finished`` is the
    journal-authoritative final count (so a trial that ends before the
    last heartbeat lands still gets credit)."""
    downs = [e["t"] for e in report["events"]
             if e["event"] in ("crash", "hang")]
    tl = report["timeline"]
    for t in downs:
        at = max((s["finished"] for s in tl if s["t"] <= t), default=0)
        after = max((s["finished"] for s in tl if s["t"] > t), default=0)
        if max(after, report["finished"]) <= at:
            return False
    return True


def run_trial(name, base, fcfg, root, ref, *, expect):
    """One fleet run; returns the per-trial report cell."""
    from repro.fleet import FleetSupervisor

    sup = FleetSupervisor(clone_requests(base), fcfg, root)
    t0 = time.perf_counter()
    report = sup.run(max_wall_s=600.0)
    wall = time.perf_counter() - t0

    tokens = {rid: r["tokens"] for rid, r in report["results"].items()}
    checks = {
        "zero_lost": not report["unaccounted"],
        "all_finished": (report["finished"] == report["n_requests"]
                         and not report["pending_checkpointed"]),
        "tokens_identical": tokens == ref,
        "restarts_crash": report["restarts"]["crash"],
        "restarts_hang": report["restarts"]["hang"],
        # fault-free must see EXACTLY zero restarts: a spurious hang
        # detection (deadline below the box's worst-case step stall)
        # is a tuning bug this benchmark exists to catch
        "restarts_as_expected": (
            (report["restarts"]["crash"] + report["restarts"]["hang"] == 0)
            if expect.get("none")
            else (report["restarts"]["crash"] >= expect.get("crash", 0)
                  and report["restarts"]["hang"] >= expect.get("hang", 0))),
        "failover_recorded": (len(report["failover_s"]["samples"])
                              >= expect.get("failovers", 0)),
        "goodput_recovered": goodput_recovered(report),
    }
    checks["pass"] = bool(
        checks["zero_lost"] and checks["all_finished"]
        and checks["tokens_identical"] and checks["restarts_as_expected"]
        and checks["failover_recorded"] and checks["goodput_recovered"])
    print(f"{name:<10s} finished={report['finished']}/"
          f"{report['n_requests']} restarts={report['restarts']} "
          f"failover_s={report['failover_s']['samples']} "
          f"identical={checks['tokens_identical']} "
          f"wall={wall:.1f}s pass={checks['pass']}", flush=True)
    cell = {
        "trial": name,
        "worker_faults": dict(fcfg.worker_faults),
        "wall_s": round(wall, 3),
        "checks": checks,
        "restarts": report["restarts"],
        "reassigned": report["reassigned"],
        "failover_s": report["failover_s"],
        "events": [e for e in report["events"]
                   if e["event"] != "launch" or e.get("restarts")],
        "workers": report["workers"],
    }
    return cell, sup.prometheus_text()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-1b-a400m-smoke",
                    help="small arch: every trial pays n_workers fresh "
                         "process startups (imports + jit)")
    ap.add_argument("--quick", action="store_true",
                    help="2 workers, fewer requests, no mixed trial "
                         "(CI smoke scale)")
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--n-requests", type=int, default=None)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--rate", type=float, default=4.0)
    ap.add_argument("--kill-rate", type=float, default=0.15,
                    help="per-step kill probability on faulted workers "
                         "(the ISSUE floor is 0.10)")
    ap.add_argument("--hang-deadline", type=float, default=None,
                    help="heartbeat-staleness deadline; default 2.5s "
                         "quick / 25s full — a worker only beats per "
                         "decode step, so the deadline must exceed the "
                         "worst-case step + jit-recompile stall under "
                         "n_workers-way CPU contention or healthy "
                         "workers get SIGKILLed as hung")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out",
                    default=str(ROOT / "experiments" / "BENCH_fleet.json"))
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.fleet import FleetConfig
    from repro.models.model import init_params

    n_workers = args.workers or (2 if args.quick else 4)
    n_req = args.n_requests or (6 if args.quick else 16)
    hang_deadline = args.hang_deadline if args.hang_deadline is not None \
        else (2.5 if args.quick else 25.0)
    cfg = get_config(args.arch)
    params = init_params(jax.random.key(0), cfg, jnp.float32)
    base = build_workload(cfg, n_req, args.seed, args.rate)

    ref, ref_mt = reference_tokens(cfg, params, base, args.slots)
    print(f"# fleet_bench: {cfg.name} workers={n_workers} n={n_req} "
          f"reference_tokens={ref_mt.generated_tokens}", flush=True)

    kr, s = args.kill_rate, args.seed
    # rate faults fire on the first incarnation only (restarts --clean),
    # so a trial's restart count is bounded by its faulted-worker count
    trials = [
        ("fault_free", {}, {"none": True}),
        ("kill",
         {i: f"kill={kr},seed={s + i}" for i in range(0, n_workers, 2)},
         {"crash": 1, "failovers": 1}),
        ("hang",
         {1: "hang_at=3:120"} if args.quick else
         {1: "hang_at=3:120", 3: f"hang=0.12:120,seed={s + 3}"},
         {"hang": 1, "failovers": 1}),
    ]
    if not args.quick:
        trials.append(
            ("mixed",
             {0: f"kill={kr},seed={s}", 1: "hang_at=4:120",
              2: f"kill_at=6,seed={s}"},
             {"crash": 2, "hang": 1, "failovers": 3}))

    def fleet_cfg(worker_faults):
        return FleetConfig(
            n_workers=n_workers, arch=args.arch, mode="continuous",
            slots=args.slots, seed=args.seed, param_seed=0,
            checkpoint_every=2, heartbeat_s=0.2,
            hang_deadline_s=hang_deadline,
            worker_faults=worker_faults)

    report = {
        "arch": cfg.name,
        "n_workers": n_workers,
        "n_requests": n_req,
        "slots": args.slots,
        "arrival": "poisson",
        "rate": args.rate,
        "kill_rate": kr,
        "hang_deadline_s": hang_deadline,
        "reference": {"generated_tokens": ref_mt.generated_tokens,
                      "requests_finished": ref_mt.requests_finished},
        "sweep": [],
        "criteria": {},
    }

    workdir = Path(tempfile.mkdtemp(prefix="fleet_bench_"))
    last_prom = ""
    try:
        for name, faults, expect in trials:
            cell, prom = run_trial(
                name, base, fleet_cfg(faults), workdir / name, ref,
                expect=expect)
            report["sweep"].append(cell)
            if faults:
                last_prom = prom
    finally:
        shutil.rmtree(workdir, ignore_errors=True)

    cells = report["sweep"]
    report["criteria"] = {
        "all_trials_pass": all(c["checks"]["pass"] for c in cells),
        "zero_lost_everywhere": all(c["checks"]["zero_lost"]
                                    and c["checks"]["all_finished"]
                                    for c in cells),
        "all_tokens_identical": all(c["checks"]["tokens_identical"]
                                    for c in cells),
        "total_restarts": {
            "crash": sum(c["restarts"]["crash"] for c in cells),
            "hang": sum(c["restarts"]["hang"] for c in cells)},
        "failover_samples": sum(len(c["failover_s"]["samples"])
                                for c in cells),
        "goodput_recovered_everywhere": all(
            c["checks"]["goodput_recovered"] for c in cells),
        "pass": all(c["checks"]["pass"] for c in cells),
    }
    report["prometheus_tail"] = [
        ln for ln in last_prom.splitlines()
        if ln.startswith(("worker_restarts_total",
                          "requests_reassigned_total",
                          "fleet_failover_s"))]
    print(json.dumps(report["criteria"], indent=2))

    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {out}")
    if not report["criteria"]["pass"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
