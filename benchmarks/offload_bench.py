"""Offloaded-decode benchmark: slab engine vs the pre-rewrite dict
engine on olmoe-mini, per cache capacity.

    PYTHONPATH=src python benchmarks/offload_bench.py \
        [--quick] [--check] [--out experiments/BENCH_offload.json]

For every capacity in the sweep, both engine implementations greedily
decode the same prompt and the report records:

  * decode wall-clock tok/s (prefill excluded; best of ``--trials``
    repeats after a warmup run, so XLA compiles never land in the
    measurement)
  * Eq.-3 modeled throughput under the serial clock
  * Eq.-3 modeled throughput under the overlapped clock (layer l's
    compute hides layer l+1's fetches)

plus the slab/dict wall speedup per capacity and its geometric mean.
Tokens are cross-checked bit-for-bit between the two engines on every
config. ``--check`` exits non-zero unless (a) the overlapped modeled
throughput >= the serial one on every swept config, (b) tokens match
everywhere, and (c) the wall speedup clears ``--min-speedup`` (the CI
perf-smoke uses a conservative floor; the checked-in report documents
the full-size numbers).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

ROOT = Path(__file__).resolve().parents[1]


def bench_capacity(cfg, params, toks, *, capacity, max_new, trials):
    """Race both engine impls at one capacity. Trials are interleaved
    (slab, dict, slab, dict, ...) so machine noise hits both equally;
    each impl reports its best trial's steady-state decode wall."""
    from repro.core.offload_engine import OffloadedMoEEngine

    engines, best = {}, {}
    for impl in ("slab", "dict"):
        eng = OffloadedMoEEngine(cfg, params, capacity=capacity, impl=impl)
        eng.generate(toks, max_new_tokens=max_new)  # warm: compiles + cache
        engines[impl] = eng
        best[impl] = None
    for _ in range(trials):
        for impl, eng in engines.items():
            # wall_time / prefill_wall_time are per-generate-call, so the
            # decode split is computed at trial time; transfer/hit counts
            # accumulate across calls, so per-trial deltas are snapshotted
            # here too (the stored metrics object keeps mutating)
            tx0 = eng.metrics.transfers
            st0 = eng.cache.stats()
            ms0 = eng.metrics.modeled_time(eng.hw)
            mo0 = eng.metrics.modeled_time_overlapped(eng.hw)
            res = eng.generate(toks, max_new_tokens=max_new)
            m = res["metrics"]
            st1 = eng.cache.stats()
            d_hits = st1.hits - st0.hits
            d_miss = st1.misses - st0.misses
            d_serial = max(eng.metrics.modeled_time(eng.hw) - ms0, 1e-12)
            d_overlap = max(
                eng.metrics.modeled_time_overlapped(eng.hw) - mo0, 1e-12)
            n_tok = max_new * toks.shape[0]
            trial = {
                "decode_wall_s": max(m.wall_time - m.prefill_wall_time, 1e-9),
                "wall_s": m.wall_time,
                "transfers": m.transfers - tx0,
                "hit_rate": d_hits / max(d_hits + d_miss, 1),
                "modeled_time_serial_s": d_serial,
                "modeled_time_overlapped_s": d_overlap,
                "modeled_tok_s_serial": n_tok / d_serial,
                "modeled_tok_s_overlapped": n_tok / d_overlap,
            }
            if best[impl] is None or trial["decode_wall_s"] < best[impl][0]["decode_wall_s"]:
                best[impl] = (trial, res)
    n_tok = max_new * toks.shape[0]
    out = {}
    for impl, (trial, res) in best.items():
        out[impl] = {
            "impl": impl,
            "capacity": capacity,
            "decode_tok_s_wall": n_tok / trial["decode_wall_s"],
            **{k: v for k, v in trial.items()},
            "tokens": np.asarray(res["tokens"]).tolist(),
        }
    return out


def traced_pass(cfg, params, toks, *, capacity, max_new, outdir):
    """One traced (untimed) generate per impl: exports the Chrome trace
    and reconciles the Eq.-3 modeled clock against the measured spans.
    Runs after the timed trials so tracing overhead never pollutes them."""
    from repro.core.offload_engine import EngineMetrics, OffloadedMoEEngine
    from repro.obs import disable_tracing, enable_tracing, reconcile

    outdir = Path(outdir)
    outdir.mkdir(parents=True, exist_ok=True)
    out = {}
    for impl in ("slab", "dict"):
        eng = OffloadedMoEEngine(cfg, params, capacity=capacity, impl=impl)
        eng.generate(toks, max_new_tokens=max_new)  # warm: compiles + cache
        eng.metrics = EngineMetrics()  # reconcile only the traced run
        tracer = enable_tracing()
        try:
            eng.generate(toks, max_new_tokens=max_new)
        finally:
            disable_tracing()
        tracer.export_chrome_trace(str(outdir / f"trace_{impl}.json"),
                                   process_name=f"offload_bench:{impl}")
        rep = reconcile(tracer.spans(), eng.metrics, eng.hw)
        (outdir / f"reconcile_{impl}.json").write_text(
            json.dumps(rep.to_json(), indent=2))
        print(f"-- {impl} (C={capacity}) Eq.-3 reconciliation --")
        print(rep.format_table())
        out[impl] = {
            "capacity": capacity,
            "ok": rep.ok,
            "serial_agreement_ratio": rep.serial_agreement_ratio,
            "measured_serial_s": rep.measured_serial_s,
            "measured_fetch_s": rep.measured_fetch_s,
            "measured_compute_s": rep.measured_compute_s,
            "measured_overlap_s": rep.measured_overlap_s,
            "unmodeled_s": rep.unmodeled_s,
            "modeled_serial_s": rep.modeled_serial_s,
            "modeled_overlapped_s": rep.modeled_overlapped_s,
        }
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmoe-mini")
    ap.add_argument("--quick", action="store_true",
                    help="short decode + fewer trials (CI perf-smoke)")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero on invariant/speedup violations")
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="--check floor for geomean wall speedup "
                         "(default: 1.5 with --quick, 5.0 full)")
    ap.add_argument("--batch", type=int, default=1,
                    help="decode batch (1 matches the wave server)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=None)
    ap.add_argument("--trials", type=int, default=None)
    ap.add_argument("--capacities", type=int, nargs="+", default=None)
    ap.add_argument("--out", default=None,
                    help="report path (default: experiments/BENCH_offload.json; "
                         "quick mode writes BENCH_offload_quick.json so the "
                         "checked-in full report is never clobbered)")
    ap.add_argument("--trace", default=None, metavar="DIR",
                    help="after the timed trials, run one traced generate "
                         "per impl at the smallest capacity, write the "
                         "Chrome trace + Eq.-3 reconciliation into DIR and "
                         "attach the reconciliation summary to the report")
    args = ap.parse_args()
    if args.out is None:
        name = "BENCH_offload_quick.json" if args.quick else "BENCH_offload.json"
        args.out = str(ROOT / "experiments" / name)

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models.model import init_params

    cfg = get_config(args.arch)
    E = cfg.moe_spec.num_experts
    caps = args.capacities or [max(E // 8, 1), E // 4, E // 2, E]
    max_new = args.max_new or (16 if args.quick else 48)
    trials = args.trials or (2 if args.quick else 5)
    min_speedup = args.min_speedup or (1.5 if args.quick else 5.0)

    params = init_params(jax.random.key(0), cfg, jnp.float32)
    toks = jax.random.randint(jax.random.key(1), (args.batch, args.prompt_len),
                              0, cfg.vocab)

    rows, failures = [], []
    for C in caps:
        per = bench_capacity(cfg, params, toks, capacity=C,
                             max_new=max_new, trials=trials)
        if per["slab"]["tokens"] != per["dict"]["tokens"]:
            failures.append(f"C={C}: slab/dict token mismatch")
        for impl in ("slab", "dict"):
            if (per[impl]["modeled_tok_s_overlapped"]
                    < per[impl]["modeled_tok_s_serial"] * (1 - 1e-9)):
                failures.append(f"C={C} {impl}: overlapped < serial throughput")
        speedup = (per["slab"]["decode_tok_s_wall"]
                   / per["dict"]["decode_tok_s_wall"])
        row = {
            "capacity": C,
            "slab": {k: v for k, v in per["slab"].items() if k != "tokens"},
            "dict": {k: v for k, v in per["dict"].items() if k != "tokens"},
            "wall_speedup_slab_over_dict": speedup,
        }
        rows.append(row)
        print(f"C={C:3d}  slab {per['slab']['decode_tok_s_wall']:8.2f} tok/s"
              f"  dict {per['dict']['decode_tok_s_wall']:8.2f} tok/s"
              f"  speedup {speedup:5.2f}x"
              f"  modeled serial/overlap "
              f"{per['slab']['modeled_tok_s_serial']:8.1f}/"
              f"{per['slab']['modeled_tok_s_overlapped']:8.1f} tok/s")

    geomean = float(np.exp(np.mean(
        [np.log(r["wall_speedup_slab_over_dict"]) for r in rows])))
    reconciled = None
    if args.trace:
        reconciled = traced_pass(cfg, params, toks, capacity=min(caps),
                                 max_new=max_new, outdir=args.trace)
    report = {
        "arch": args.arch,
        "batch": args.batch,
        "prompt_len": args.prompt_len,
        "max_new": max_new,
        "trials": trials,
        "quick": args.quick,
        "capacities": caps,
        "rows": rows,
        "geomean_wall_speedup": geomean,
    }
    if reconciled is not None:
        report["reconcile"] = reconciled
    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(report, indent=2))
    print(f"geomean wall speedup {geomean:.2f}x -> {out}")

    if args.check:
        if geomean < min_speedup:
            failures.append(
                f"geomean speedup {geomean:.2f}x < floor {min_speedup}x")
        if failures:
            print("CHECK FAILED:\n  " + "\n  ".join(failures))
            return 1
        print("CHECK OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
