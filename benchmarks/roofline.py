"""Roofline analysis from the dry-run artifacts (deliverable g).

Three terms per (arch x shape), single-pod mesh (256 x v5e):

    compute    = HLO_FLOPs_per_dev / peak_FLOP/s
    memory     = HLO_bytes_per_dev / HBM_bw
    collective = collective_bytes_per_dev / ICI_bw

Hardware constants (v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (we charge collectives against one link's bandwidth — the
conservative single-axis serialization assumption; 2D-mesh collectives
that stripe across both axes would be up to 2x faster).

MODEL_FLOPS = 6*N*D (train: fwd+bwd) or 2*N*D (prefill/decode, fwd only),
N = active params, D = global tokens processed by the step. The ratio
MODEL_FLOPS / (HLO_FLOPs * chips) flags remat/redundancy waste.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops_total: float
    useful_ratio: float
    dominant: str
    lever: str
    raw: dict

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def _lever(dom: str, rec: dict) -> str:
    mode = rec["mode"]
    kinds = rec["collectives"]["bytes_by_kind"]
    if dom == "collective":
        top = max(kinds, key=kinds.get) if kinds else "?"
        if top == "all-gather" and rec.get("fsdp"):
            return ("all-gather dominated (FSDP weight gathers): overlap gathers with "
                    "compute or widen the model axis to shrink per-layer gather size")
        if top == "all-to-all":
            return ("all-to-all dominated (expert dispatch): cut capacity_factor or "
                    "use hierarchical a2a within pods before crossing the pod axis")
        if top == "all-reduce":
            return ("all-reduce dominated (TP partial sums / grads): reduce-scatter + "
                    "overlap, or shift TP degree toward data parallelism")
        return f"{top} dominated: restructure sharding to localize that exchange"
    if dom == "memory":
        if mode == "decode":
            return ("HBM-bound KV/weight streaming (expected for decode): quantize the "
                    "cache/weights (int4 resident experts) or raise batch to amortize")
        return "HBM-bound: fuse elementwise chains, bf16 master-cast, larger matmul tiles"
    if mode == "decode":
        return "compute-bound decode (unusual): check padding waste in dispatch buffers"
    return ("compute-bound (good): approach peak by keeping MXU-aligned tiles; "
            "remaining gap is remat recompute and causal-mask waste")


def load_records(mesh: str = "single") -> List[dict]:
    recs = []
    for f in sorted(DRYRUN_DIR.glob(f"*__{mesh}.json")):
        recs.append(json.loads(f.read_text()))
    return recs


def analyze(rec: dict) -> Roofline:
    chips = rec["n_devices"]
    flops_dev = rec.get("flops_per_device") or 0.0
    # TPU-adjusted: exclude XLA:CPU mixed-precision convert traffic
    bytes_dev = rec.get("tpu_adjusted_bytes_per_device",
                        rec.get("bytes_accessed_per_device") or 0.0)
    coll_dev = rec["collectives"]["total_bytes"]
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_dev / ICI_BW
    n_act = rec["param_counts"]["active"]
    tokens = rec["global_batch"] * (rec["seq_len"] if rec["mode"] == "train" else
                                    (rec["seq_len"] if rec["mode"] == "prefill" else 1))
    mf = (6 if rec["mode"] == "train" else 2) * n_act * tokens
    hlo_total = flops_dev * chips
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dom = max(terms, key=terms.get)
    return Roofline(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        model_flops=mf, hlo_flops_total=hlo_total,
        useful_ratio=mf / hlo_total if hlo_total else 0.0,
        dominant=dom, lever=_lever(dom, rec), raw=rec,
    )


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def table(rows: List[Roofline]) -> str:
    out = [
        "| arch | shape | compute | memory | collective | dominant | useful-FLOPs |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        out.append(
            f"| {r.arch} | {r.shape} | {fmt_s(r.compute_s)} | {fmt_s(r.memory_s)} | "
            f"{fmt_s(r.collective_s)} | **{r.dominant}** | {r.useful_ratio:.2f} |"
        )
    return "\n".join(out)


def main(mesh: str = "single"):
    rows = [analyze(r) for r in load_records(mesh)]
    rows.sort(key=lambda r: (r.arch, r.shape))
    print(table(rows))
    out = {
        f"{r.arch}__{r.shape}": {
            "compute_s": r.compute_s, "memory_s": r.memory_s,
            "collective_s": r.collective_s, "dominant": r.dominant,
            "useful_ratio": r.useful_ratio, "model_flops": r.model_flops,
            "hlo_flops_total": r.hlo_flops_total, "lever": r.lever,
        }
        for r in rows
    }
    path = DRYRUN_DIR.parent / f"roofline_{mesh}.json"
    path.write_text(json.dumps(out, indent=1))
    print(f"\nwrote {path}")
    # candidates per the hillclimb-selection rule
    worst = min(rows, key=lambda r: r.useful_ratio if r.dominant == "compute" else 1e9)
    collb = max(rows, key=lambda r: r.collective_s / max(r.bound_s, 1e-12)
                if r.dominant == "collective" else 0)
    print("\nmost collective-bound:", collb.arch, collb.shape)
    print("worst useful-ratio compute-bound:", worst.arch, worst.shape)
    return rows


if __name__ == "__main__":
    import sys

    main(sys.argv[1] if len(sys.argv) > 1 else "single")
