"""Post-SPMD HLO analysis for the roofline (deliverable g).

XLA's ``compiled.cost_analysis()`` counts ``while`` bodies ONCE, which
under-counts everything inside a layer scan by the trip count. This
module re-derives per-device totals directly from ``compiled.as_text()``:

  * dot FLOPs        — 2 * prod(result dims) * prod(contracting dims),
                       fusion-inner dots included
  * bytes accessed   — per top-level instruction: result bytes + operand
                       bytes (symbol table of instruction result shapes;
                       fusions are one unit, their internals don't count)
  * collective bytes — result-shape bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute

Instructions inside ``while`` bodies are multiplied by the loop trip
count (XLA annotates ``known_trip_count`` on scan-derived loops).
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1, "token": 0,
    "opaque": 0,
}

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*->.*\{\s*$")
_INSTR_RE = re.compile(r"^(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"^((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+)?([\w\-]+)\(")
_WHILE_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"rhs_contracting_dims=\{([0-9,]*)\}")
_HDR_PARAM_RE = re.compile(r"([\w.\-]+)\s*:\s*([a-z0-9]+)\[([0-9,]*)\]")


def shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


def _all_shape_bytes(text: str) -> int:
    return sum(shape_bytes(m.group(1), m.group(2)) for m in _SHAPE_RE.finditer(text))


def _dims(dims: str) -> List[int]:
    return [int(d) for d in dims.split(",")] if dims else []


@dataclass
class Instr:
    name: str
    op: str
    result_bytes: int
    result_dims: List[int]
    operands: List[str]
    line: str


@dataclass
class HloCosts:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    convert_bytes: float = 0.0  # dtype-convert traffic: an XLA:CPU artifact
    # for mixed-precision dots (the TPU MXU consumes bf16 operands with f32
    # accumulation natively) — subtract for the TPU-adjusted memory term
    coll_by_kind: Dict[str, float] = field(default_factory=dict)
    coll_counts: Dict[str, float] = field(default_factory=dict)

    @property
    def tpu_adjusted_bytes(self) -> float:
        return max(self.bytes_accessed - self.convert_bytes, 0.0)

    def scaled(self, k: float) -> "HloCosts":
        return HloCosts(
            self.flops * k, self.bytes_accessed * k, self.collective_bytes * k,
            self.convert_bytes * k,
            {a: b * k for a, b in self.coll_by_kind.items()},
            {a: b * k for a, b in self.coll_counts.items()},
        )

    def add(self, o: "HloCosts"):
        self.flops += o.flops
        self.bytes_accessed += o.bytes_accessed
        self.collective_bytes += o.collective_bytes
        self.convert_bytes += o.convert_bytes
        for k, v in o.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0) + v
        for k, v in o.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + v


def _dot_flops(line: str, result_dims: List[int], rhs_dims: List[int]) -> float:
    """2 * prod(result dims) * prod(rhs contracting dims)."""
    m = _CONTRACT_RE.search(line)
    if m is None:
        return 0.0
    rhs = rhs_dims
    if not rhs:
        # fall back to shapes inline in the argument list (rare)
        args = line.split(" dot(", 1)[1] if " dot(" in line else ""
        shapes = _SHAPE_RE.findall(args)
        rhs = _dims(shapes[1][1]) if len(shapes) > 1 else []
    cdims = [int(c) for c in m.group(1).split(",")] if m.group(1) else []
    k = 1
    for c in cdims:
        if c < len(rhs):
            k *= rhs[c]
    return 2.0 * math.prod(result_dims or [1]) * k


_COMMENT_RE = re.compile(r"/\*.*?\*/")


def _parse_instr(line: str) -> Optional[Instr]:
    line = _COMMENT_RE.sub("", line)  # strip /*index=N*/ tuple comments
    m = _INSTR_RE.match(line)
    if not m:
        return None
    name, rest = m.group(1), m.group(2)
    # result shape(s): text before the op token '...('
    om = re.search(r"\)?\s*([a-z][\w\-]*)\(", rest)
    opm = re.match(r"^(\([^=]*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+([\w\-]+)\(", rest)
    if opm:
        shape_text, op = opm.group(1), opm.group(2)
    else:
        # e.g. constants without parens / oddly formatted lines
        sm = _SHAPE_RE.search(rest)
        shape_text = sm.group(0) if sm else ""
        head = rest.split("(")[0].split()
        op = head[-1] if head else (rest.split()[0] if rest.split() else "unknown")
    result_bytes = _all_shape_bytes(shape_text)
    # operand names: inside the first (...) after the op token
    operands = []
    paren = rest.find(op + "(")
    if paren >= 0:
        depth = 0
        j = paren + len(op)
        start = j
        for j in range(start, len(rest)):
            if rest[j] == "(":
                depth += 1
            elif rest[j] == ")":
                depth -= 1
                if depth == 0:
                    break
        arglist = rest[start : j + 1]
        operands = _OPERAND_RE.findall(arglist)
    sm = _SHAPE_RE.search(shape_text)
    rd = _dims(sm.group(2)) if sm else []
    return Instr(name, op, result_bytes, rd, operands, line)


def _split_computations(hlo: str):
    """Returns (entry, {name: [instruction lines]}, {name: header line})."""
    comps: Dict[str, List[str]] = {}
    headers: Dict[str, str] = {}
    entry = None
    cur = None
    depth = 0
    for line in hlo.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _COMP_HDR_RE.match(stripped)
            if m and stripped.endswith("{"):
                cur = m.group(1)
                comps[cur] = []
                headers[cur] = stripped
                depth = 1
                if stripped.startswith("ENTRY"):
                    entry = cur
        else:
            depth += stripped.count("{") - stripped.count("}")
            if depth <= 0:
                cur = None
            else:
                comps[cur].append(stripped)
    return entry, comps, headers


def _trip_count_from_cond(cond_lines: List[str]) -> int:
    best = 1
    for line in cond_lines:
        for m in _CONST_RE.finditer(line):
            best = max(best, int(m.group(1)))
    return best


class HloAnalyzer:
    def __init__(self, hlo: str):
        self.entry, self.comps, self.headers = _split_computations(hlo)
        self._memo: Dict[str, HloCosts] = {}
        self._fusion_dots: Dict[str, float] = {}
        # per-computation symbol tables: name -> dims (for dot rhs lookup)
        self._dims: Dict[str, Dict[str, List[int]]] = {}

    def _symbols(self, comp: str) -> Dict[str, List[int]]:
        if comp in self._dims:
            return self._dims[comp]
        table: Dict[str, List[int]] = {}
        hdr = self.headers.get(comp, "")
        # header params: "name: f32[a,b]"
        for m in _HDR_PARAM_RE.finditer(hdr.split("->")[0]):
            table[m.group(1)] = _dims(m.group(3))
        for line in self.comps.get(comp, []):
            ins = _parse_instr(line)
            if ins is not None:
                table[ins.name] = ins.result_dims
        self._dims[comp] = table
        return table

    def _instr_dot_flops(self, comp: str, ins: Instr) -> float:
        if " dot(" not in ins.line:
            return 0.0
        table = self._symbols(comp)
        rhs = table.get(ins.operands[1], []) if len(ins.operands) > 1 else []
        return _dot_flops(ins.line, ins.result_dims, rhs)

    def _fusion_dus_update_bytes(self, comp: str) -> Optional[int]:
        """If the fused computation is a (convert-wrapped) dynamic-update-
        slice of a big buffer, return the update-operand bytes: on TPU the
        fusion aliases in/out and only the slice is written. XLA:CPU wraps
        the DUS in bf16-emulation converts (no native bf16 ALU), which my
        byte accounting must not charge as whole-buffer rewrites."""
        lines = self.comps.get(comp, [])
        if not lines:
            return None
        sizes: Dict[str, int] = {}
        dus_update: Optional[int] = None
        root_name = None
        producer: Dict[str, Instr] = {}
        for line in lines:
            ins = _parse_instr(line)
            if ins is None:
                continue
            sizes[ins.name] = ins.result_bytes
            producer[ins.name] = ins
            if line.lstrip().startswith("ROOT"):
                root_name = ins.name
        if root_name is None:
            return None
        # follow converts/copies/bitcasts from the root to the core op
        cur = producer.get(root_name)
        for _ in range(4):
            if cur is None:
                return None
            if cur.op == "dynamic-update-slice":
                if len(cur.operands) > 1:
                    upd = producer.get(cur.operands[1])
                    # update may itself be convert-wrapped; charge its size
                    return sizes.get(cur.operands[1], 0)
                return None
            if cur.op in ("convert", "copy", "bitcast") and cur.operands:
                cur = producer.get(cur.operands[0])
            else:
                return None
        return None

    def _fusion_dot_flops(self, comp: str, stack=()) -> float:
        """Sum of dot FLOPs inside a fused computation (recursively)."""
        if comp in self._fusion_dots:
            return self._fusion_dots[comp]
        if comp in stack or comp not in self.comps:
            return 0.0
        total = 0.0
        for line in self.comps[comp]:
            ins = _parse_instr(line)
            if ins is None:
                continue
            total += self._instr_dot_flops(comp, ins)
            if ins.op in ("fusion", "call"):
                cm = _CALLS_RE.search(line)
                if cm:
                    total += self._fusion_dot_flops(cm.group(1), stack + (comp,))
        self._fusion_dots[comp] = total
        return total

    def costs(self, comp: Optional[str] = None, stack=()) -> HloCosts:
        comp = comp or self.entry
        if comp is None or comp not in self.comps or comp in stack:
            return HloCosts()
        if comp in self._memo:
            return self._memo[comp]
        total = HloCosts()
        sizes: Dict[str, int] = {}
        # header params have sizes too (operand byte lookup)
        hdr = self.headers.get(comp, "")
        for m in _HDR_PARAM_RE.finditer(hdr.split("->")[0]):
            sizes[m.group(1)] = shape_bytes(m.group(2), m.group(3))
        for line in self.comps[comp]:
            ins = _parse_instr(line)
            if ins is None:
                continue
            sizes[ins.name] = ins.result_bytes
            op = ins.op
            if op in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast", "bitcast-convert"):
                continue
            if op in ("while", "copy", "conditional", "call"):
                # call-site buffer passes are aliased in practice; the
                # body's real traffic is accounted inside (x trip count)
                if op == "while":
                    wm = _WHILE_RE.search(line)
                    if wm:
                        cond, body = wm.group(1), wm.group(2)
                        tm = _TRIP_RE.search(line)
                        trips = int(tm.group(1)) if tm else _trip_count_from_cond(
                            self.comps.get(cond, []))
                        total.add(self.costs(body, stack + (comp,)).scaled(trips))
                elif op == "call" or op == "conditional":
                    for cm in _CALLS_RE.finditer(line):
                        if cm.group(1) in self.comps:
                            total.add(self.costs(cm.group(1), stack + (comp,)))
                continue
            if op in ("convert", "convert-element-type"):
                total.convert_bytes += 2 * ins.result_bytes
            if op in ("dynamic-update-slice", "scatter"):
                # in-place update: touched bytes ~ 2x the update operand,
                # not the whole buffer (XLA aliases the result)
                upd = sizes.get(ins.operands[1], 0) if len(ins.operands) > 1 else 0
                total.bytes_accessed += 2 * upd
            elif op == "fusion":
                cm0 = _CALLS_RE.search(line)
                dus_upd = (
                    self._fusion_dus_update_bytes(cm0.group(1)) if cm0 else None
                )
                if dus_upd is not None:
                    # cache-update fusion: charge the slice, not the buffer
                    # (the whole-buffer rewrite is XLA:CPU's bf16-emulation
                    # breaking aliasing; a TPU bf16 DUS aliases in place)
                    total.bytes_accessed += 2 * dus_upd
                else:
                    operand_bytes = sum(sizes.get(o, 0) for o in ins.operands)
                    total.bytes_accessed += ins.result_bytes + operand_bytes
            else:
                operand_bytes = sum(sizes.get(o, 0) for o in ins.operands)
                total.bytes_accessed += ins.result_bytes + operand_bytes
            total.flops += self._instr_dot_flops(comp, ins)
            base_op = op[:-6] if op.endswith("-start") else op
            if base_op in COLLECTIVES:
                total.collective_bytes += ins.result_bytes
                total.coll_by_kind[base_op] = (
                    total.coll_by_kind.get(base_op, 0) + ins.result_bytes
                )
                total.coll_counts[base_op] = total.coll_counts.get(base_op, 0) + 1
            if op == "fusion":
                cm = _CALLS_RE.search(line)
                if cm:
                    total.flops += self._fusion_dot_flops(cm.group(1), (comp,))
            elif op == "while":
                wm = _WHILE_RE.search(line)
                if wm:
                    cond, body = wm.group(1), wm.group(2)
                    tm = _TRIP_RE.search(line)
                    trips = int(tm.group(1)) if tm else _trip_count_from_cond(
                        self.comps.get(cond, [])
                    )
                    total.add(self.costs(body, stack + (comp,)).scaled(trips))
            elif op in ("call", "conditional", "async-start"):
                for cm in _CALLS_RE.finditer(line):
                    sub = cm.group(1)
                    if sub in self.comps:
                        total.add(self.costs(sub, stack + (comp,)))
        self._memo[comp] = total
        return total


# ---------------------------------------------------------------------------
# Back-compat surface used by dryrun/tests
# ---------------------------------------------------------------------------


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    count_by_kind: Dict[str, int] = field(default_factory=lambda: defaultdict(int))

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_by_kind.values()))

    def as_dict(self) -> dict:
        return {
            "total_bytes": self.total_bytes,
            "bytes_by_kind": dict(self.bytes_by_kind),
            "count_by_kind": dict(self.count_by_kind),
        }


def top_contributors(hlo: str, n: int = 15):
    """Profiling aid for §Perf: the top-n (dot flops) and (bytes) lines,
    each scaled by its total loop-trip multiplicity, with metadata names."""
    an = HloAnalyzer(hlo)
    # compute multiplicity of each computation: entry=1; while body *= trips
    mult: Dict[str, float] = {an.entry: 1.0}
    order = [an.entry]
    i = 0
    while i < len(order):
        comp = order[i]
        i += 1
        for line in an.comps.get(comp, []):
            ins = _parse_instr(line)
            if ins is None:
                continue
            if ins.op == "while":
                wm = _WHILE_RE.search(line)
                if wm:
                    tm = _TRIP_RE.search(line)
                    trips = int(tm.group(1)) if tm else _trip_count_from_cond(
                        an.comps.get(wm.group(1), []))
                    body = wm.group(2)
                    if body not in mult:
                        mult[body] = mult[comp] * trips
                        order.append(body)
            elif ins.op in ("fusion", "call", "conditional"):
                cm = _CALLS_RE.search(line)
                if cm and cm.group(1) not in mult:
                    mult[cm.group(1)] = mult[comp]
                    order.append(cm.group(1))

    flops_rows, bytes_rows = [], []
    for comp, m in mult.items():
        syms = an._symbols(comp)
        sizes = {k: math.prod(v or [1]) for k, v in syms.items()}
        for line in an.comps.get(comp, []):
            ins = _parse_instr(line)
            if ins is None or ins.op in ("parameter", "constant",
                                         "get-tuple-element", "tuple", "bitcast"):
                continue
            meta = ""
            mm = re.search(r'op_name="([^"]*)"', line)
            if mm:
                meta = mm.group(1)[-70:]
            fl = an._instr_dot_flops(comp, ins) * m
            if fl > 0:
                flops_rows.append((fl, ins.op, ins.name, meta))
            if ins.op != "fusion":  # fusion internals double-count bytes
                b = ins.result_bytes * m
                if b > 0:
                    bytes_rows.append((b, ins.op, ins.name, meta))
    flops_rows.sort(reverse=True)
    bytes_rows.sort(reverse=True)
    return flops_rows[:n], bytes_rows[:n]


def collective_bytes(hlo: str) -> CollectiveStats:
    costs = HloAnalyzer(hlo).costs()
    st = CollectiveStats()
    st.bytes_by_kind.update(costs.coll_by_kind)
    st.count_by_kind.update({k: int(v) for k, v in costs.coll_counts.items()})
    return st


def full_costs(hlo: str) -> HloCosts:
    return HloAnalyzer(hlo).costs()
