"""One benchmark function per paper table/figure. Each returns a list of
CSV rows (name, value, derived-details). Hardware-time numbers are the
Eq.-3 model on the v5e profile (counts are exact simulation).
"""
from __future__ import annotations

import numpy as np

from repro.core.baselines import BASELINES, make_engine
from repro.core.cache_sim import hard_cache_misses, topk_request
from repro.core.offload_engine import HardwareProfile, OffloadedMoEEngine
from repro.core.predictor import (
    PromptEmbedder,
    init_predictor,
    predict_scores,
    train_predictor,
)
from repro.data.synthetic import eval_batches
from repro.inference.engine import routing_trace
from repro.training.trainer import eval_nll

from .common import Pipeline, finetune_variant, get_pipeline

import jax.numpy as jnp

HW = HardwareProfile()
GEN = 24  # decode tokens per measurement (paper uses 64/256)


def _run(pipe, params, *, capacity, policy="lfu", quantized=False, prefetch=None,
         batch=2, gen=GEN, stream_all=False, cpu_execute=False, gamma=0.9,
         cluster=1, seed=100):
    eng = OffloadedMoEEngine(
        pipe.cfg, params, capacity=capacity, policy=policy, quantized=quantized,
        stream_all=stream_all, cpu_execute=cpu_execute, gamma=gamma, hw=HW,
    )
    if prefetch is not None:
        eng.prefetch(prefetch)
    prompts = pipe.prompts(batch, seed=seed, cluster=cluster)
    res = eng.generate(prompts, max_new_tokens=gen)
    return res


# ---------------------------------------------------------------------------
# Table 1: throughput vs cache size (base model)
# ---------------------------------------------------------------------------


def table1_cache_size(pipe: Pipeline):
    E = pipe.cfg.moe_spec.num_experts
    rows = []
    for frac, C in [("25%", E // 4), ("50%", E // 2), ("100%", E)]:
        r = _run(pipe, pipe.base_params, capacity=C)
        rows.append((f"table1/throughput_tok_s/cache_{frac}",
                     round(r["throughput_tok_s"], 2),
                     f"TxPerLayer={r['transfers_per_layer']:.1f}"))
    return rows


# ---------------------------------------------------------------------------
# Fig 1a/1b: transfer counts + routing concentration, base vs fine-tuned
# ---------------------------------------------------------------------------


def fig1_transfers_concentration(pipe: Pipeline):
    C = pipe.cfg.melinoe_cache_capacity()
    rows = []
    tx = {}
    for name, params in [("base", pipe.base_params), ("finetuned", pipe.ft_params)]:
        r = _run(pipe, params, capacity=C)
        tx[name] = r["metrics"].transfers
        rows.append((f"fig1a/transfers/{name}", tx[name],
                     f"hit_rate={r['cache_stats'].hit_rate:.3f}"))
    rows.append(("fig1a/transfer_reduction_x", round(tx["base"] / max(tx["finetuned"], 1), 2),
                 "paper reports 3.03x on OLMoE"))
    # Fig 1b: share of activations captured by the top-8 experts per sequence
    for name, params in [("base", pipe.base_params), ("finetuned", pipe.ft_params)]:
        prompts = pipe.prompts(4, seed=11)
        _, probs = routing_trace(pipe.cfg, params, prompts, max_new=GEN)
        # probs (B, L, T, E): per-sequence mean activation -> top-8 share
        act = probs.mean(axis=(1, 2))  # (B, E)
        share = np.sort(act, -1)[:, -8:].sum(-1) / act.sum(-1)
        rows.append((f"fig1b/top8_share/{name}", round(float(share.mean()), 4),
                     "paper: ~31% base on OLMoE, higher after FT"))
    return rows


# ---------------------------------------------------------------------------
# Table 2: downstream quality (held-out NLL as the offline metric)
# ---------------------------------------------------------------------------


def table2_quality(pipe: Pipeline):
    ev = eval_batches(pipe.lm, 2, 8)
    rows = []
    nll_b = eval_nll(pipe.cfg, pipe.base_params, ev)
    nll_f = eval_nll(pipe.cfg, pipe.ft_params, ev)
    rows.append(("table2/heldout_nll/base", round(nll_b, 4), ""))
    rows.append(("table2/heldout_nll/melinoe", round(nll_f, 4),
                 "paper: quality retained or improved"))
    # quantized baselines degrade quality (Mixtral-Offloading/FLoE analogue):
    # evaluate the base model with int4 experts
    from repro.core.quant import dequantize, quantize
    import jax

    qparams = jax.tree.map(lambda a: a, pipe.base_params)
    g = qparams["groups"]["g0"]["p0"]["ffn"]
    for t in ("wg", "wu", "wd"):
        w = g[t]
        qt = quantize(w.reshape(-1, w.shape[-1]), group=32, iters=2)
        g[t] = dequantize(qt, w.dtype).reshape(w.shape)
    nll_q = eval_nll(pipe.cfg, qparams, ev)
    rows.append(("table2/heldout_nll/quant_cache_int4", round(nll_q, 4),
                 "quantized-expert baselines trade quality"))
    return rows


# ---------------------------------------------------------------------------
# Table 3: fine-tuning vs prefetching decomposition
# ---------------------------------------------------------------------------


def _train_predictor_for(pipe: Pipeline, params, n_prompts=24, gen=12, seed=55):
    import jax

    emb = PromptEmbedder(pipe.cfg.vocab)
    prompts = pipe.prompts(n_prompts, seed=seed)
    _, probs = routing_trace(pipe.cfg, params, prompts, max_new=gen)
    targets = jnp.asarray(probs.mean(axis=2))  # (N, L, E)
    embs = jnp.stack([emb(jnp.asarray(p)) for p in prompts])
    pp = init_predictor(jax.random.key(3), targets.shape[1], targets.shape[2])
    pp, hist = train_predictor(pp, embs, targets, epochs=10)
    return emb, pp, hist


def table3_finetune_prefetch(pipe: Pipeline):
    C = pipe.cfg.melinoe_cache_capacity()
    rows = []
    r_base = _run(pipe, pipe.base_params, capacity=C)
    rows.append(("table3/base/throughput", round(r_base["throughput_tok_s"], 2),
                 f"TxPerLayer={r_base['transfers_per_layer']:.1f}"))
    r_ft = _run(pipe, pipe.ft_params, capacity=C)
    rows.append(("table3/finetuned/throughput", round(r_ft["throughput_tok_s"], 2),
                 f"TxPerLayer={r_ft['transfers_per_layer']:.1f}"))
    emb, pp, hist = _train_predictor_for(pipe, pipe.ft_params)
    prompts = pipe.prompts(2, seed=100, cluster=1)
    scores = predict_scores(pp, emb(jnp.asarray(prompts)).mean(0))
    r_pf = _run(pipe, pipe.ft_params, capacity=C, prefetch=scores)
    rows.append(("table3/finetuned+prefetch/throughput", round(r_pf["throughput_tok_s"], 2),
                 f"TxPerLayer={r_pf['transfers_per_layer']:.1f} predictorKL={hist[-1]:.3f}"))
    return rows


# ---------------------------------------------------------------------------
# Fig 3-like: MELINOE vs baseline systems
# ---------------------------------------------------------------------------


def fig3_baselines(pipe: Pipeline):
    C = pipe.cfg.melinoe_cache_capacity()
    rows = []
    for name, spec in sorted(BASELINES.items()):
        params = pipe.ft_params if name == "melinoe" else pipe.base_params
        eng = make_engine(pipe.cfg, params, spec, capacity=C, hw=HW)
        if spec.use_predictor:
            emb, pp, _ = _train_predictor_for(pipe, params, n_prompts=16, gen=8)
            prompts = pipe.prompts(2, seed=100, cluster=1)
            eng.prefetch(predict_scores(pp, emb(jnp.asarray(prompts)).mean(0)))
        res = eng.generate(pipe.prompts(2, seed=100, cluster=1), max_new_tokens=GEN)
        rows.append((f"fig3/throughput/{name}", round(res["throughput_tok_s"], 2),
                     f"transfers={res['metrics'].transfers} host={res['metrics'].host_executed}"))
    return rows


# ---------------------------------------------------------------------------
# Fig 4: lambda ablations (transfers + quality)
# ---------------------------------------------------------------------------


def fig4_lambda_ablation(pipe: Pipeline):
    C = pipe.cfg.melinoe_cache_capacity()
    ev = eval_batches(pipe.lm, 1, 8)
    rows = []
    for lam_cs in (0.0, 0.5, 5.0):
        params = finetune_variant(pipe, lambda_cs=lam_cs, lambda_rm=0.1)
        r = _run(pipe, params, capacity=C)
        nll = eval_nll(pipe.cfg, params, ev)
        rows.append((f"fig4/lambda_cs={lam_cs}",
                     round(r["transfers_per_layer"], 1),
                     f"nll={nll:.3f} tput={r['throughput_tok_s']:.2f}"))
    for lam_rm in (0.0, 1.0):
        params = finetune_variant(pipe, lambda_cs=0.5, lambda_rm=lam_rm)
        r = _run(pipe, params, capacity=C)
        nll = eval_nll(pipe.cfg, params, ev)
        rows.append((f"fig4/lambda_rm={lam_rm}",
                     round(r["transfers_per_layer"], 1),
                     f"nll={nll:.3f}"))
    return rows


# ---------------------------------------------------------------------------
# Fig 5: batch size scaling
# ---------------------------------------------------------------------------


def fig5_batch_size(pipe: Pipeline):
    C = pipe.cfg.melinoe_cache_capacity()
    rows = []
    for B in (1, 2, 4):
        r_b = _run(pipe, pipe.base_params, capacity=C, batch=B, cluster=None)
        r_f = _run(pipe, pipe.ft_params, capacity=C, batch=B, cluster=None)
        rows.append((f"fig5/batch={B}/speedup",
                     round(r_f["throughput_tok_s"] / max(r_b["throughput_tok_s"], 1e-9), 2),
                     f"base={r_b['throughput_tok_s']:.1f} ft={r_f['throughput_tok_s']:.1f}"))
    return rows


# ---------------------------------------------------------------------------
# Table 5: composing fine-tuning with prior baselines
# ---------------------------------------------------------------------------


def table5_compose(pipe: Pipeline):
    C = pipe.cfg.melinoe_cache_capacity()
    rows = []
    for name in ("quant_cache", "static_lru"):
        for pname, params in [("base", pipe.base_params), ("+finetune", pipe.ft_params)]:
            eng = make_engine(pipe.cfg, params, BASELINES[name], capacity=C, hw=HW)
            res = eng.generate(pipe.prompts(2, seed=100, cluster=1), max_new_tokens=GEN)
            rows.append((f"table5/{name}/{pname}", round(res["throughput_tok_s"], 2),
                         f"transfers={res['metrics'].transfers}"))
    return rows


# ---------------------------------------------------------------------------
# Table 12 (D.5): quantized resident experts
# ---------------------------------------------------------------------------


def table12_quant(pipe: Pipeline):
    C = pipe.cfg.melinoe_cache_capacity()
    rows = []
    for name, params in [("base", pipe.base_params), ("finetuned", pipe.ft_params)]:
        r_fp = _run(pipe, params, capacity=C)
        r_q = _run(pipe, params, capacity=3 * C, quantized=True)
        rows.append((f"table12/{name}/fp_C={C}", round(r_fp["throughput_tok_s"], 2),
                     f"Tx={r_fp['metrics'].transfers}"))
        rows.append((f"table12/{name}/int4_C={3*C}", round(r_q["throughput_tok_s"], 2),
                     f"Tx={r_q['metrics'].transfers}"))
    return rows


# ---------------------------------------------------------------------------
# D.7/D.8: eviction gamma x policy on a fixed routing trace
# ---------------------------------------------------------------------------


def table13_eviction(pipe: Pipeline):
    from repro.core.expert_cache import simulate_trace

    prompts = pipe.prompts(4, seed=31)
    _, probs = routing_trace(pipe.cfg, pipe.ft_params, prompts, max_new=GEN)
    # probs (B, L, T, E) -> trace (T_total, L, K)
    K = pipe.cfg.moe_spec.top_k
    ids = np.argsort(-probs, axis=-1)[..., :K]  # (B, L, T, K)
    trace = np.concatenate([ids[b].transpose(1, 0, 2) for b in range(ids.shape[0])], 0)
    C = pipe.cfg.melinoe_cache_capacity()
    rows = []
    for policy in ("lru", "lfu"):
        st = simulate_trace(trace, capacity=C, policy=policy)
        rows.append((f"table13/{policy}", st.transfers, f"hit={st.hit_rate:.3f}"))
    for gamma in (0.1, 0.5, 0.9):
        st = simulate_trace(trace, capacity=C, policy="gamma", gamma=gamma)
        rows.append((f"table13/gamma={gamma}", st.transfers, f"hit={st.hit_rate:.3f}"))
    return rows


# ---------------------------------------------------------------------------
# D.6: soft cache capacity used in the loss
# ---------------------------------------------------------------------------


def fig12_soft_capacity(pipe: Pipeline):
    C_eval = pipe.cfg.melinoe_cache_capacity()
    rows = []
    E = pipe.cfg.moe_spec.num_experts
    for C_loss in (2, C_eval, E // 2):
        params = finetune_variant(pipe, cache_capacity=C_loss)
        r = _run(pipe, params, capacity=C_eval)
        rows.append((f"fig12/soft_C={C_loss}", round(r["transfers_per_layer"], 1),
                     f"eval_C={C_eval}"))
    return rows


ALL_BENCHES = {
    "table1_cache_size": table1_cache_size,
    "fig1_transfers_concentration": fig1_transfers_concentration,
    "table2_quality": table2_quality,
    "table3_finetune_prefetch": table3_finetune_prefetch,
    "fig3_baselines": fig3_baselines,
    "fig4_lambda_ablation": fig4_lambda_ablation,
    "fig5_batch_size": fig5_batch_size,
    "table5_compose": table5_compose,
    "table12_quant": table12_quant,
    "table13_eviction": table13_eviction,
    "fig12_soft_capacity": fig12_soft_capacity,
}
