"""Recovery benchmark: crash-at-random-step, restore, replay.

    PYTHONPATH=src python benchmarks/recovery_bench.py [--quick] \
        [--out experiments/BENCH_recovery.json]

Runs the offloaded wave server under a write-ahead journal with
per-wave checkpoints, kills it at a seeded random engine decode step
(``crash_at`` fault), and restores twice from the same journal:

  warm — ``engine.revive()`` prefetches the checkpointed resident set
         back into the slabs before serving resumes;
  cold — policy scores restored but the slabs start empty, so resumed
         serving re-pays the demand misses.

The servers run in demand-paging mode (``use_prefetch=False``): with
the per-wave scheduler prefetch on, ``prefill_from_scores`` resets
every layer's resident set to the wave's Top-C at the first resumed
wave, so warm and cold converge before a single demand access and the
revival's value is invisible. Demand paging is the configuration where
the checkpointed working set actually carries across the restart —
the cache warms only through use, which is exactly what the
checkpoint preserved.

Reported per crash point: recovery wall time (journal replay +
revival), revival transfer cost, and post-restart transfer churn
(demand transfers after the restore). Acceptance criteria baked into
the report:

  * every restored run finishes token-identical to the uninterrupted
    reference, warm and cold alike (greedy resumption is exact);
  * warm revival's mean post-restart demand transfers are strictly
    below cold restart's (checkpointing the cache state preserves the
    MELINOE working set across the crash);
  * the invariant watchdog (strict, every wave) never fires:
    ``audit_violations_total`` stays 0 across every restore.
"""
from __future__ import annotations

import argparse
import json
import shutil
import sys
import tempfile
import time
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

ROOT = Path(__file__).resolve().parents[1]


def build_workload(cfg, params, n_req, seed):
    from repro.data.synthetic import ClusterLM, SyntheticConfig
    from repro.serving import (TrafficConfig, prefill_expert_scores,
                               synthesize_workload)

    lm = ClusterLM(SyntheticConfig(vocab=cfg.vocab, seq_len=48, seed=seed))
    tcfg = TrafficConfig(
        n_requests=n_req, arrival="poisson", rate=8.0,
        prompt_len=(8, 16), max_new_tokens=(4, 12), seed=seed + 1,
    )
    reqs = synthesize_workload(lm, tcfg)
    prefill_expert_scores(cfg, params, reqs)
    return reqs


def clone_requests(reqs):
    from repro.serving import ServeRequest

    return [
        ServeRequest(
            rid=r.rid, prompt=r.prompt, max_new_tokens=r.max_new_tokens,
            temperature=r.temperature, stop_tokens=r.stop_tokens,
            arrival_time=r.arrival_time, cluster=r.cluster,
            expert_scores=r.expert_scores,
        )
        for r in reqs
    ]


def make_server(cfg, params, capacity, wave_size, policy):
    from repro.serving import OffloadedWaveServer

    # demand paging: see the module docstring — per-wave prefetch would
    # overwrite the revived resident set before it is ever consulted
    return OffloadedWaveServer(cfg, params, capacity=capacity,
                               wave_size=wave_size, use_prefetch=False,
                               policy=policy)


def audit_violations():
    from repro.obs import REGISTRY

    return sum(v for k, v in REGISTRY.snapshot().items()
               if k.startswith("audit_violations_total"))


def restore_and_replay(cfg, params, capacity, wave_size, policy, jdir, *,
                       warm):
    """One restore leg: recover the journal, revive the engine (warm or
    cold), serve the remainder. Returns tokens + the cost breakdown."""
    from repro.recovery import recover
    from repro.serving import RequestQueue  # noqa: F401 (queue built below)

    t0 = time.perf_counter()
    state = recover(jdir)
    recover_s = time.perf_counter() - t0
    assert state is not None and state.kind == "wave"

    srv = make_server(cfg, params, capacity, wave_size, policy)
    eng = srv.engine
    revival = {"loaded": 0, "bytes": 0, "modeled_s": 0.0}
    t0 = time.perf_counter()
    if state.engine is not None:
        eng.metrics.load_state(state.engine["metrics"])
        revival = eng.revive(state.engine["cache"], warm=warm)
    revive_s = time.perf_counter() - t0

    demand0 = eng.metrics.transfers
    v0 = audit_violations()
    # journaling is off for the measurement leg: both restores replay
    # from the SAME on-disk journal
    results, mt = srv.run(state.build_queue(None), state.metrics,
                          audit_every=1, resume=state)
    assert eng.audit() == []
    return {
        "pending_at_restore": len(state.pending),
        "finished_at_restore": len(state.results),
        "recover_wall_s": recover_s,
        "revive_wall_s": revive_s,
        "revival_transfers": revival["loaded"],
        "revival_bytes": revival["bytes"],
        "revival_modeled_s": revival["modeled_s"],
        "post_restart_demand_transfers": eng.metrics.transfers - demand0,
        "audit_violations": audit_violations() - v0,
        "generated_tokens": mt.generated_tokens,
    }, {r.rid: r.tokens.tolist() for r in results}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmoe-mini")
    ap.add_argument("--quick", action="store_true",
                    help="fewer crash points (CI smoke scale)")
    ap.add_argument("--n-requests", type=int, default=None)
    ap.add_argument("--n-crashes", type=int, default=None)
    ap.add_argument("--wave-size", type=int, default=2)
    ap.add_argument("--capacity", type=int, default=0, help="0 => E/4")
    ap.add_argument("--policy", default="lru",
                    choices=["lru", "lfu", "gamma"],
                    help="cache eviction policy (lru default: a revived "
                         "set's stale entries age out; restored LFU "
                         "counts can pin them)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out",
                    default=str(ROOT / "experiments" / "BENCH_recovery.json"))
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.faults import (InjectedCrash, install_fault_plan,
                              uninstall_fault_plan)
    from repro.models.model import init_params
    from repro.recovery import RequestJournal
    from repro.serving import RequestQueue

    n_req = args.n_requests or (6 if args.quick else 10)
    n_crashes = args.n_crashes or (3 if args.quick else 6)
    cfg = get_config(args.arch)
    params = init_params(jax.random.key(args.seed), cfg, jnp.float32)
    capacity = args.capacity or cfg.melinoe_cache_capacity()
    base = build_workload(cfg, params, n_req, args.seed)

    # -- uninterrupted reference ----------------------------------------
    uninstall_fault_plan()
    ref_srv = make_server(cfg, params, capacity, args.wave_size, args.policy)
    ref_res, ref_mt = ref_srv.run(RequestQueue(clone_requests(base)))
    ref_tokens = {r.rid: r.tokens.tolist() for r in ref_res}
    total_steps = ref_mt.decode_steps
    print(f"# recovery_bench: {cfg.name} C={capacity} n={n_req} "
          f"engine_steps~{total_steps} transfers={ref_mt.transfers}",
          flush=True)

    # crash points: seeded random engine decode steps inside the run
    rng = np.random.default_rng(args.seed + 11)
    hi = max(total_steps - 2, 4)
    crash_steps = sorted(int(k) for k in
                         rng.choice(np.arange(3, hi), size=min(n_crashes, hi - 3),
                                    replace=False))

    report = {
        "arch": cfg.name,
        "capacity": capacity,
        "n_requests": n_req,
        "wave_size": args.wave_size,
        "policy": args.policy,
        "reference": {"transfers": ref_mt.transfers,
                      "generated_tokens": ref_mt.generated_tokens,
                      "engine_steps": total_steps},
        "crash_steps": crash_steps,
        "sweep": [],
        "criteria": {},
    }

    all_identical, any_violation = True, 0
    warm_demand, cold_demand = [], []
    workdir = Path(tempfile.mkdtemp(prefix="recovery_bench_"))
    try:
        for k in crash_steps:
            jdir = workdir / f"crash_{k}"
            jr = RequestJournal(jdir)
            srv = make_server(cfg, params, capacity, args.wave_size, args.policy)
            install_fault_plan(f"crash_at={k},seed={args.seed}")
            crashed = False
            try:
                srv.run(RequestQueue(clone_requests(base)),
                        journal=jr, checkpoint_every=1)
            except InjectedCrash:
                crashed = True
            finally:
                jr.close()
                uninstall_fault_plan()

            cell = {"crash_at": k, "crashed": crashed, "restores": {}}
            for mode, warm in (("warm", True), ("cold", False)):
                leg, tokens = restore_and_replay(
                    cfg, params, capacity, args.wave_size, args.policy,
                    jdir, warm=warm)
                leg["tokens_identical"] = tokens == ref_tokens
                all_identical &= leg["tokens_identical"]
                any_violation += leg["audit_violations"]
                (warm_demand if warm else cold_demand).append(
                    leg["post_restart_demand_transfers"])
                cell["restores"][mode] = leg
                print(f"crash_at={k:<4d} {mode:4s} "
                      f"pending={leg['pending_at_restore']} "
                      f"revive_tx={leg['revival_transfers']} "
                      f"post_demand_tx={leg['post_restart_demand_transfers']} "
                      f"identical={leg['tokens_identical']} "
                      f"recover={leg['recover_wall_s'] * 1e3:.1f}ms",
                      flush=True)
            report["sweep"].append(cell)
    finally:
        shutil.rmtree(workdir, ignore_errors=True)

    mean_warm = float(np.mean(warm_demand)) if warm_demand else 0.0
    mean_cold = float(np.mean(cold_demand)) if cold_demand else 0.0
    report["criteria"] = {
        "all_tokens_identical": bool(all_identical),
        "audit_violations_total": int(any_violation),
        "mean_warm_post_restart_demand_transfers": mean_warm,
        "mean_cold_post_restart_demand_transfers": mean_cold,
        "warm_revival_reduces_demand_transfers": mean_warm < mean_cold,
        "pass": bool(all_identical and any_violation == 0
                     and mean_warm < mean_cold),
    }
    print(json.dumps(report["criteria"], indent=2))

    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(report, indent=2) + "\n")
    print(f"wrote {out}")
    if not report["criteria"]["pass"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
