"""Benchmark harness: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME] [--skip-slow]

Prints ``name,value,derived`` CSV rows. Slow entries (extra fine-tunes)
are the lambda/soft-capacity ablations; --skip-slow omits them.
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

SLOW = {"fig4_lambda_ablation", "fig12_soft_capacity"}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="fewer train steps (default)")
    ap.add_argument("--full", action="store_true", help="paper-scale steps (slow on CPU)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--skip-slow", action="store_true")
    args = ap.parse_args()

    from benchmarks.common import get_pipeline
    from benchmarks.paper_tables import ALL_BENCHES

    pipe = get_pipeline(quick=not args.full)
    names = [args.only] if args.only else list(ALL_BENCHES)
    print("name,value,derived")
    failures = []
    for name in names:
        if args.skip_slow and name in SLOW:
            continue
        fn = ALL_BENCHES[name]
        t0 = time.time()
        try:
            for row in fn(pipe):
                print(f"{row[0]},{row[1]},{row[2]}")
        except Exception as e:  # keep the harness going, report at the end
            failures.append((name, repr(e)))
            print(f"{name},ERROR,{e!r}")
        sys.stdout.flush()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
