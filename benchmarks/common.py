"""Shared benchmark pipeline: one cached pretrain + MELINOE fine-tune of
the reproduction model (olmoe-mini) that every paper-table benchmark
reuses. CPU-scale; artifacts cached under experiments/bench_cache/."""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.lora import lora_scale
from repro.data.synthetic import ClusterLM, SyntheticConfig, eval_batches
from repro.models.model import init_params
from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.optim import OptConfig
from repro.training.trainer import melinoe_finetune, merge_lora, pretrain

CACHE = Path(__file__).resolve().parents[1] / "experiments" / "bench_cache"

ARCH = "olmoe-mini"
SEQ = 48
BATCH = 8


@dataclass
class Pipeline:
    cfg: object
    lm: ClusterLM
    base_params: dict
    ft_params: dict  # LoRA merged
    quick: bool

    def prompts(self, n: int, length: int = 24, seed: int = 100,
                cluster: Optional[int] = None) -> np.ndarray:
        rng = np.random.default_rng(seed)
        return np.stack(
            [self.lm.sample_sequence(rng, cluster=cluster)[0][:length] for _ in range(n)]
        ).astype(np.int32)


def _steps(quick: bool):
    return (40, 24) if quick else (160, 80)


def finetune_variant(pipe: Pipeline, *, steps: Optional[int] = None, seed: int = 7,
                     **melinoe_overrides) -> dict:
    """Fine-tune from the cached base with modified melinoe hyper-params
    (lambda/gamma/C ablations). Returns merged params."""
    import dataclasses

    cfg = pipe.cfg
    if melinoe_overrides:
        cfg = dataclasses.replace(
            cfg, melinoe=dataclasses.replace(cfg.melinoe, **melinoe_overrides)
        )
    steps = steps or _steps(pipe.quick)[1]
    ft = melinoe_finetune(cfg, pipe.base_params, pipe.lm.batches(BATCH, seed=seed),
                          steps=steps, log_every=10**9, verbose=False)
    return merge_lora(cfg, ft.params, ft.lora, lora_scale(cfg.melinoe))


def get_pipeline(quick: bool = False, seed: int = 0) -> Pipeline:
    cfg = get_config(ARCH)
    pre_steps, ft_steps = _steps(quick)
    key = f"{ARCH}-{SEQ}-{BATCH}-{pre_steps}-{ft_steps}-{seed}-v2"
    tag = hashlib.md5(key.encode()).hexdigest()[:10]
    CACHE.mkdir(parents=True, exist_ok=True)
    base_p = CACHE / f"base_{tag}.ckpt"
    ft_p = CACHE / f"ft_{tag}.ckpt"
    lm = ClusterLM(SyntheticConfig(vocab=cfg.vocab, seq_len=SEQ, seed=seed))

    like = jax.eval_shape(lambda: init_params(jax.random.key(seed), cfg, jnp.float32))
    if base_p.exists() and ft_p.exists():
        base, _, _ = load_checkpoint(base_p, like)
        ft, _, _ = load_checkpoint(ft_p, like)
        return Pipeline(cfg, lm, base, ft, quick)

    print(f"[bench] training pipeline ({pre_steps}+{ft_steps} steps, cache {tag})")
    res = pretrain(cfg, lm.batches(BATCH, seed=seed + 1), steps=pre_steps,
                   log_every=max(pre_steps // 4, 1), verbose=True)
    ft = melinoe_finetune(cfg, res.params, lm.batches(BATCH, seed=seed + 2),
                          steps=ft_steps, log_every=max(ft_steps // 4, 1), verbose=True)
    merged = merge_lora(cfg, ft.params, ft.lora, lora_scale(cfg.melinoe))
    save_checkpoint(base_p, res.params, metadata={"stage": "base"})
    save_checkpoint(ft_p, merged, metadata={"stage": "melinoe-merged"})
    (CACHE / f"history_{tag}.json").write_text(
        json.dumps({"pretrain": res.history, "finetune": ft.history})
    )
    return Pipeline(cfg, lm, res.params, merged, quick)


def heldout(pipe: Pipeline, n: int = 2):
    return eval_batches(pipe.lm, n, BATCH)
