"""Re-derive analyzer fields (FLOPs / bytes / collectives) of dry-run
JSON records from their saved .hlo.txt files — lets the HLO cost model
evolve without recompiling 80 combos.

    PYTHONPATH=src python -m benchmarks.reanalyze [dir]
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

from .hlo_analysis import full_costs

DEFAULT = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def main(d: Path):
    n = 0
    for hlo_path in sorted(d.glob("*.hlo.txt")):
        json_path = d / (hlo_path.name.replace(".hlo.txt", ".json"))
        if not json_path.exists():
            continue
        rec = json.loads(json_path.read_text())
        costs = full_costs(hlo_path.read_text())
        rec["flops_per_device"] = costs.flops
        rec["bytes_accessed_per_device"] = costs.bytes_accessed
        rec["convert_bytes_per_device"] = costs.convert_bytes
        rec["tpu_adjusted_bytes_per_device"] = costs.tpu_adjusted_bytes
        rec["collectives"] = {
            "total_bytes": costs.collective_bytes,
            "bytes_by_kind": costs.coll_by_kind,
            "count_by_kind": {k: int(v) for k, v in costs.coll_counts.items()},
        }
        json_path.write_text(json.dumps(rec, indent=1))
        n += 1
    print(f"reanalyzed {n} records in {d}")


if __name__ == "__main__":
    main(Path(sys.argv[1]) if len(sys.argv) > 1 else DEFAULT)
