"""Distributed correctness + lowering, run in subprocesses so we can set
XLA_FLAGS (8 host devices) before jax initializes."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


def run_py(code: str, timeout=900):
    env = {"PYTHONPATH": f"{ROOT}/src:{ROOT}", "PATH": "/usr/bin:/bin"}
    import os

    env.update({k: v for k, v in os.environ.items() if k not in env})
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_moe_sharded_equals_local():
    """shard_map expert parallelism must be numerically identical to the
    single-device dispatch path."""
    out = run_py(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import MoESpec
        from repro.models.moe import apply_moe_local, apply_moe_sharded, init_moe
        from repro.models.runtime import Runtime
        from repro.launch.mesh import make_debug_mesh

        spec = MoESpec(num_experts=8, top_k=2, d_ff=32)
        d = 16
        params = init_moe(jax.random.key(0), d, spec, jnp.float32)
        x = jax.random.normal(jax.random.key(1), (32, d))
        y_loc, p_loc = apply_moe_local(params, x, spec, Runtime(zero_drop=True))
        mesh = make_debug_mesh(2, 4)
        rt = Runtime(mesh=mesh, zero_drop=True)
        y_sh, p_sh = jax.jit(
            lambda pp, xx: apply_moe_sharded(pp, xx, spec, rt)
        )(params, x)
        err = float(jnp.max(jnp.abs(y_loc - y_sh)))
        print("ERR", err)
        assert err < 2e-4, err
        print("OK")
        """
    )
    assert "OK" in out


def test_train_step_sharded_matches_single_device():
    """One pjit train step on a 2x2 mesh == the same step on 1 device."""
    out = run_py(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.models import init_params
        from repro.models.runtime import Runtime
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.steps import build_train_step
        from repro.training.optim import OptConfig, init_opt_state

        cfg = get_config("granite-moe-1b-a400m-smoke")
        params = init_params(jax.random.key(0), cfg, jnp.float32)
        opt = init_opt_state(params)
        toks = jax.random.randint(jax.random.key(1), (8, 32), 0, cfg.vocab)
        batch = {"tokens": toks, "labels": toks}
        oc = OptConfig(peak_lr=1e-3, total_steps=10)

        p1, o1, m1 = jax.jit(build_train_step(cfg, Runtime(), oc, melinoe=True))(params, opt, batch)
        mesh = make_debug_mesh(2, 2)
        rt = Runtime(mesh=mesh)
        p2, o2, m2 = jax.jit(build_train_step(cfg, rt, oc, melinoe=True))(params, opt, batch)
        dl = abs(float(m1["loss"]) - float(m2["loss"]))
        dp = max(float(jnp.abs(a - b).max()) for a, b in
                 zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
        print("dl", dl, "dp", dp)
        assert dl < 5e-3 and dp < 5e-2, (dl, dp)
        print("OK")
        """
    )
    assert "OK" in out


@pytest.mark.parametrize("arch", ["granite-moe-1b-a400m-smoke", "zamba2-7b-smoke"])
def test_multipod_lowering_has_collectives(arch):
    out = run_py(
        f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, sys
        from repro.configs import get_config
        from repro.configs.base import ShapeSpec
        from repro.models.model import param_shapes
        from repro.models.runtime import Runtime
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.specs import input_specs
        from repro.launch.steps import build_train_step, train_shardings
        from repro.training.optim import OptConfig, init_opt_state
        from benchmarks.hlo_analysis import collective_bytes

        cfg = get_config("{arch}")
        mesh = make_debug_mesh(2, 2, pod=2)
        rt = Runtime(mesh=mesh)
        sh = ShapeSpec("t", 64, 8, "train")
        specs = input_specs(cfg, sh)
        pshapes = param_shapes(cfg)
        oshapes = jax.eval_shape(init_opt_state, pshapes)
        step = build_train_step(cfg, rt, OptConfig(total_steps=10), melinoe=True)
        ps, os_, bs = train_shardings(cfg, rt, specs)
        compiled = jax.jit(step, in_shardings=(ps, os_, bs)).lower(
            pshapes, oshapes, specs).compile()
        st = collective_bytes(compiled.as_text())
        print("BYTES", st.total_bytes, dict(st.count_by_kind))
        assert st.total_bytes > 0
        print("OK")
        """
    )
    assert "OK" in out
