"""Property tests for the cache-simulation loss (paper App C.1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: deterministic local fallback
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.cache_sim import (
    cache_sim_loss,
    hard_cache_misses,
    soft_cache_states,
    topk_request,
)


def random_probs(seed, B, T, E, conc=1.0):
    logits = jax.random.normal(jax.random.key(seed), (B, T, E)) * conc
    return jax.nn.softmax(logits, -1)


# ---------------------------------------------------------------------------
# Request vector
# ---------------------------------------------------------------------------


@given(st.integers(0, 100), st.integers(2, 24), st.integers(1, 6))
@settings(max_examples=25, deadline=None)
def test_request_l1_mass_is_k(seed, E, K):
    """||r||_1 = K for every estimator (paper: 'Thus ||r||_1 = K')."""
    K = min(K, E)
    p = random_probs(seed, 1, 4, E)[0]
    for mode in ("soft", "hard", "hard_st"):
        r = topk_request(p, K, mode)
        np.testing.assert_allclose(np.asarray(r.sum(-1)), K, rtol=1e-5)
        assert (np.asarray(r) >= -1e-6).all()


def test_request_hard_st_forward_is_binary():
    p = random_probs(3, 1, 5, 8)[0]
    r = topk_request(p, 3, "hard_st")
    vals = np.unique(np.round(np.asarray(r), 5))
    assert set(vals) <= {0.0, 1.0}


# ---------------------------------------------------------------------------
# Soft cache state — Prop C.3
# ---------------------------------------------------------------------------


@given(st.integers(0, 50), st.floats(0.05, 1.0), st.integers(2, 10))
@settings(max_examples=25, deadline=None)
def test_soft_cache_l1_is_capacity(seed, gamma, C):
    """Prop C.3: the Z-normalized recursion preserves ||c||_1 = C."""
    E, T, K = 16, 12, 4
    C = min(C, E)
    p = random_probs(seed, 1, T, E)[0]
    r = topk_request(p, K, "soft")
    cs, cfin = soft_cache_states(r, gamma, C, K)
    np.testing.assert_allclose(np.asarray(cs.sum(-1)), C, rtol=1e-4)
    np.testing.assert_allclose(float(cfin.sum()), C, rtol=1e-4)


def test_soft_cache_matches_closed_form():
    """Prop C.3: recursive update == explicitly normalized discounted counts."""
    E, T, K, C, gamma = 8, 10, 2, 4, 0.7
    r = topk_request(random_probs(7, 1, T, E)[0], K, "hard")
    cs, _ = soft_cache_states(r, gamma, C, K)
    # closed form: Count_t = gamma^{t-1} * C/E * 1 + sum_{i<t} gamma^{t-1-i} r_i
    counts = np.full(E, C / E)
    for t in range(T):
        expect = counts / counts.sum() * C
        np.testing.assert_allclose(np.asarray(cs[t]), expect, rtol=1e-4, atol=1e-5)
        counts = gamma * counts + np.asarray(r[t])


# ---------------------------------------------------------------------------
# Lemma C.4: dL_cs/dgamma <= 0
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_gamma_effect_small_on_unstructured_routing(seed):
    """Lemma C.4 claims dL_cs/dgamma <= 0, but its derivative neglects
    dZ/dgamma (recorded in EXPERIMENTS.md): on *unstructured* random
    routing the loss can mildly INCREASE with gamma (~1-2%). We pin the
    honest statement: gamma's effect is tiny absent reuse structure..."""
    E, B, T, K, C = 16, 4, 24, 4, 4
    p = random_probs(seed, B, T, E, conc=2.0)
    losses = [
        float(cache_sim_loss(p, top_k=K, gamma=g, cache_capacity=C, request_mode="hard"))
        for g in (0.1, 0.5, 0.9, 0.99)
    ]
    spread = (max(losses) - min(losses)) / abs(np.mean(losses))
    assert spread < 0.05, losses


def test_hard_cache_misses_decrease_with_gamma_on_persistent_routing():
    """The deployment-relevant monotonicity (paper App D.7 / Fig 13): with
    persistent per-sequence preferences, the *hard* gamma-discounted
    Top-C cache of Def C.1 misses less as gamma grows (less myopic).

    NOTE (EXPERIMENTS.md 'Lemma C.4 refinement'): the *soft normalized*
    L_cs is mildly INCREASING in gamma on the same traces — the paper's
    dL_cs/dgamma <= 0 derivation drops the dZ/dgamma term. The soft loss
    is still a faithful *ranking* proxy across routing patterns (tested
    above), which is what fine-tuning needs."""
    E, T, K, C = 16, 256, 4, 4
    key = jax.random.key(0)
    pref = jnp.zeros((4, 1, E)).at[:, :, :5].set(2.5)
    p = jax.nn.softmax(pref + 0.8 * jax.random.normal(key, (4, T, E)), -1)
    r = topk_request(p, K, "hard")
    miss = {}
    for g in (0.05, 0.5, 0.95):
        miss[g] = float(
            sum(hard_cache_misses(r[b], g, C) for b in range(r.shape[0]))
        )
    assert miss[0.95] <= miss[0.5] <= miss[0.05] * 1.02, miss


# ---------------------------------------------------------------------------
# Behavior: concentration lowers the loss; soft proxy tracks hard misses
# ---------------------------------------------------------------------------


def test_concentrated_routing_has_lower_loss():
    E, B, T, K, C = 32, 4, 32, 4, 8
    diverse = random_probs(0, B, T, E)
    conc = jax.nn.softmax(
        jnp.zeros((B, T, E)).at[..., :K].set(6.0)
        + 0.05 * jax.random.normal(jax.random.key(1), (B, T, E)), -1
    )
    l_div = cache_sim_loss(diverse, top_k=K, gamma=0.9, cache_capacity=C)
    l_conc = cache_sim_loss(conc, top_k=K, gamma=0.9, cache_capacity=C)
    assert float(l_conc) < float(l_div)


def test_soft_proxy_correlates_with_hard_misses():
    """The differentiable loss must rank routing patterns like the real
    cache simulator (else fine-tuning optimizes the wrong thing)."""
    E, T, K, C = 16, 64, 2, 4
    soft_vals, hard_vals = [], []
    for conc in [0.0, 0.5, 1.0, 2.0, 4.0]:
        key = jax.random.key(int(conc * 10))
        base = jax.random.normal(key, (1, T, E))
        pref = jnp.zeros((E,)).at[:3].set(conc)
        p = jax.nn.softmax(base + pref, -1)
        soft_vals.append(float(cache_sim_loss(p, top_k=K, gamma=0.9, cache_capacity=C)))
        r = topk_request(p[0], K, "hard")
        hard_vals.append(float(hard_cache_misses(r, 0.9, C)))
    # both sequences should be (weakly) decreasing with concentration
    assert soft_vals[0] > soft_vals[-1]
    assert hard_vals[0] > hard_vals[-1]
    corr = np.corrcoef(soft_vals, hard_vals)[0, 1]
    assert corr > 0.8, (soft_vals, hard_vals)


def test_soft_proxy_correlates_with_real_eviction_cache():
    """replay_trace_misses routes through LayerExpertCache.access_batch —
    the exact cache the offload engine runs. The soft loss must rank
    routing concentration the same way this ground truth does."""
    from repro.core.cache_sim import replay_trace_misses

    E, T, K, C = 16, 64, 2, 4
    soft_vals, real_vals = [], []
    for conc in [0.0, 1.0, 2.0, 4.0]:
        key = jax.random.key(int(conc * 10) + 1)
        base = jax.random.normal(key, (1, T, E))
        pref = jnp.zeros((E,)).at[:3].set(conc)
        p = jax.nn.softmax(base + pref, -1)
        soft_vals.append(float(cache_sim_loss(p, top_k=K, gamma=0.9, cache_capacity=C)))
        _, eids = jax.lax.top_k(p[0], K)
        real_vals.append(replay_trace_misses(np.asarray(eids), C, "gamma", 0.9,
                                             num_experts=E))
    assert soft_vals[0] > soft_vals[-1]
    assert real_vals[0] > real_vals[-1]
    corr = np.corrcoef(soft_vals, real_vals)[0, 1]
    assert corr > 0.8, (soft_vals, real_vals)


@given(st.integers(0, 40), st.floats(0.1, 0.99), st.integers(2, 8))
@settings(max_examples=20, deadline=None)
def test_assoc_scan_equals_sequential(seed, gamma, C):
    """§Perf beyond-paper optimization: the associative-scan evaluation of
    the soft cache must equal the paper's sequential recursion exactly."""
    from repro.core.cache_sim import soft_cache_states_assoc

    E, T, K = 16, 33, 4
    C = min(C, E)
    p = random_probs(seed, 1, T, E)[0]
    r = topk_request(p, K, "soft")
    c1, f1 = soft_cache_states(r, gamma, C, K)
    c2, f2 = soft_cache_states_assoc(r, gamma, C)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), atol=2e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), atol=2e-5, rtol=1e-4)


def test_assoc_loss_and_grads_match_scan():
    from repro.core.cache_sim import cache_sim_loss as csl

    logits = jax.random.normal(jax.random.key(9), (2, 40, 16))
    vals, grads = {}, {}
    for impl in ("scan", "assoc"):
        f = lambda lg: csl(jax.nn.softmax(lg, -1), top_k=4, gamma=0.9,
                           cache_capacity=4, impl=impl)
        vals[impl] = float(f(logits))
        grads[impl] = jax.grad(f)(logits)
    np.testing.assert_allclose(vals["scan"], vals["assoc"], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(grads["scan"]), np.asarray(grads["assoc"]),
                               atol=1e-6, rtol=1e-5)


def test_gradient_flows_soft_mode():
    E, T, K, C = 8, 16, 2, 2
    logits = jax.random.normal(jax.random.key(5), (2, T, E))

    def f(lg):
        return cache_sim_loss(jax.nn.softmax(lg, -1), top_k=K, gamma=0.9,
                              cache_capacity=C, request_mode="soft")

    g = jax.grad(f)(logits)
    assert float(jnp.abs(g).sum()) > 0
    assert not bool(jnp.any(jnp.isnan(g)))
