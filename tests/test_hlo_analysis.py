"""HLO collective-bytes parser (roofline input)."""
from benchmarks.hlo_analysis import collective_bytes, shape_bytes

HLO = """\
HloModule jit_step, entry_computation_layout={()->f32[]}

%body.1 (arg: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %aa = f32[8,16]{1,0} all-gather(%x), replica_groups={}
  ROOT %t = (s32[], f32[8,16]) tuple(%i, %aa)
}

%cond.1 (arg: (s32[], f32[8,16])) -> pred[] {
  %c = s32[] constant(24)
  ROOT %cmp = pred[] compare(%i, %c), direction=LT
}

ENTRY %main.1 (p: f32[8,16]) -> f32[] {
  %ar = f32[4,4]{1,0} all-reduce(%p), to_apply=%add
  %w = (s32[], f32[8,16]) while(%init), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"24"}}
  %a2a = (f32[2,8]{1,0}, f32[2,8]{1,0}) all-to-all(%u, %v), replica_groups={}
  ROOT %r = f32[] constant(0)
}
"""


def test_shape_bytes():
    assert shape_bytes("f32", "8,16") == 512
    assert shape_bytes("bf16", "4") == 8
    assert shape_bytes("pred", "") == 1


def test_collective_accounting_with_while_trip_count():
    st = collective_bytes(HLO)
    d = st.as_dict()
    # all-reduce: 4*4*4 = 64 bytes, once
    assert d["bytes_by_kind"]["all-reduce"] == 64
    # all-gather inside while body: 8*16*4 = 512 bytes * 24 trips
    assert d["bytes_by_kind"]["all-gather"] == 512 * 24
    assert d["count_by_kind"]["all-gather"] == 24
    # tupled all-to-all: two f32[2,8] results = 128 bytes
    assert d["bytes_by_kind"]["all-to-all"] == 128
