"""Synthetic cluster-preference corpus: determinism + learnable structure."""
import numpy as np

from repro.data.synthetic import ClusterLM, SyntheticConfig, eval_batches


def test_deterministic():
    lm1 = ClusterLM(SyntheticConfig(seed=3))
    lm2 = ClusterLM(SyntheticConfig(seed=3))
    b1 = next(lm1.batches(4, seed=5))
    b2 = next(lm2.batches(4, seed=5))
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_tokens_in_range_and_cluster_structure():
    cfg = SyntheticConfig(vocab=512, n_clusters=4, seq_len=64)
    lm = ClusterLM(cfg)
    rng = np.random.default_rng(0)
    seqs, ks = [], []
    for _ in range(40):
        s, k = lm.sample_sequence(rng)
        seqs.append(s)
        ks.append(k)
    toks = np.stack(seqs)
    assert toks.min() >= 0 and toks.max() < cfg.vocab
    # sequences from the same cluster share far more vocabulary than
    # cross-cluster pairs (the premise MELINOE exploits)
    ks = np.asarray(ks)
    def overlap(a, b):
        return len(set(a) & set(b)) / len(set(a) | set(b))
    same, diff = [], []
    for i in range(len(seqs)):
        for j in range(i + 1, len(seqs)):
            (same if ks[i] == ks[j] else diff).append(overlap(seqs[i], seqs[j]))
    assert np.mean(same) > 2 * np.mean(diff)


def test_eval_batches_reproducible():
    lm = ClusterLM(SyntheticConfig())
    a = eval_batches(lm, 2, 4)
    b = eval_batches(lm, 2, 4)
    np.testing.assert_array_equal(a[0]["tokens"], b[0]["tokens"])
