"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see 1 CPU device;
distributed paths are exercised in subprocesses (test_distributed.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def rand(key, *shape, scale=1.0, dtype=jnp.float32):
    return jax.random.normal(jax.random.key(key), shape, dtype) * scale
