"""Observability subsystem: span nesting/timing, zero-overhead disabled
tracer, Chrome-trace schema validity, metrics registry snapshot/diff,
serving TTFT/ITL + rolling windows, and the Eq.-3 reconciliation
invariants on a real (dict-impl) engine run."""
import json
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.offload_engine import EngineMetrics, OffloadedMoEEngine
from repro.models.model import init_params
from repro.obs import (
    MetricsRegistry,
    NULL_TRACER,
    Tracer,
    chrome_trace,
    clock_span,
    disable_tracing,
    enable_tracing,
    get_tracer,
    reconcile,
    validate_chrome_trace,
)
from repro.obs.reconcile import OTHER
from repro.serving.metrics import ServerMetrics


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("granite-moe-1b-a400m-smoke")
    params = init_params(jax.random.key(0), cfg, jnp.float32)
    return cfg, params


@pytest.fixture(autouse=True)
def _tracing_off():
    """Every test starts and ends with the global tracer disabled."""
    disable_tracing()
    yield
    disable_tracing()


# ---------------------------------------------------------------------------
# trace.py
# ---------------------------------------------------------------------------


def test_span_nesting_and_timing():
    tr = Tracer()
    with tr.span("outer", layer=0):
        time.sleep(0.002)
        with tr.span("inner"):
            time.sleep(0.001)
    spans = tr.spans()
    assert [s.name for s in spans] == ["inner", "outer"]  # close order
    inner, outer = spans
    assert inner.depth == 1 and outer.depth == 0
    # monotone + containment: inner lives within outer, durations positive
    assert outer.dur >= inner.dur > 0
    assert outer.t0 <= inner.t0 and inner.t1 <= outer.t1 + 1e-9
    assert outer.args == {"layer": 0}


def test_instants_and_drain():
    tr = Tracer()
    tr.instant("cache.access", layer=1, misses=2)
    with tr.span("s"):
        pass
    s, i = tr.drain()
    assert len(s) == 1 and len(i) == 1
    assert i[0].args["misses"] == 2
    assert tr.spans() == [] and tr.instants() == []


def test_buffer_bound():
    tr = Tracer(max_records=10)
    for _ in range(25):
        with tr.span("x"):
            pass
    assert len(tr.spans()) <= 10
    assert tr.dropped > 0


def test_disabled_tracer_is_noop():
    assert get_tracer() is NULL_TRACER
    assert NULL_TRACER.enabled is False
    ctx = NULL_TRACER.span("anything", layer=3)
    with ctx:
        pass
    # the no-op context is shared — nothing is allocated or stored
    assert NULL_TRACER.span("other") is ctx
    assert NULL_TRACER.spans() == [] and NULL_TRACER.instants() == []


def test_enable_disable_roundtrip():
    tr = enable_tracing()
    assert get_tracer() is tr and tr.enabled
    with get_tracer().span("a"):
        pass
    assert len(tr.spans()) == 1
    disable_tracing()
    assert get_tracer() is NULL_TRACER


def test_clock_span_always_times():
    # disabled: .dur still measures, nothing recorded
    with clock_span("serve.decode_step") as cs:
        time.sleep(0.001)
    assert cs.dur > 0
    # enabled: same interval is also a span on the tracer
    tr = enable_tracing()
    with clock_span("serve.decode_step", active=2) as cs:
        time.sleep(0.001)
    assert cs.dur > 0
    spans = tr.spans()
    assert len(spans) == 1 and spans[0].name == "serve.decode_step"
    assert abs(spans[0].dur - cs.dur) < 5e-3


def test_chrome_trace_schema_valid():
    tr = Tracer()
    with tr.span("engine.decode_step", step=0):
        with tr.span("moe.compute", layer=1, experts=np.int64(4)):
            pass
    tr.instant("serve.retire", rid=np.int32(7))
    obj = tr.to_chrome_trace(process_name="test")
    assert validate_chrome_trace(obj) == []
    # round-trips through JSON (numpy args coerced)
    obj2 = json.loads(json.dumps(obj))
    assert validate_chrome_trace(obj2) == []
    evs = [e for e in obj2["traceEvents"] if e["ph"] == "X"]
    assert {e["name"] for e in evs} == {"engine.decode_step", "moe.compute"}
    assert all(e["ts"] >= 0 and e["dur"] >= 0 for e in evs)


def test_chrome_trace_exporters(tmp_path):
    tr = Tracer()
    with tr.span("a"):
        pass
    p = tmp_path / "trace.json"
    tr.export_chrome_trace(str(p), process_name="t")
    assert validate_chrome_trace(json.load(open(p))) == []
    pj = tmp_path / "trace.jsonl"
    tr.export_jsonl(str(pj))
    lines = [json.loads(l) for l in open(pj)]
    assert lines and lines[0]["kind"] == "span" and lines[0]["name"] == "a"


def test_validate_rejects_bad_traces():
    assert validate_chrome_trace({"traceEvents": []}) != []  # no real events
    assert validate_chrome_trace({"nope": 1}) != []
    bad = {"traceEvents": [{"name": "x", "ph": "X", "ts": -1, "pid": 0,
                            "tid": 0, "dur": 1}]}
    assert any("ts" in e for e in validate_chrome_trace(bad))


def test_tracer_thread_safety():
    tr = Tracer()

    def work(n):
        for i in range(50):
            with tr.span("t", n=n, i=i):
                pass

    threads = [threading.Thread(target=work, args=(n,)) for n in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    spans = tr.spans()
    assert len(spans) == 200
    assert all(s.depth == 0 for s in spans)  # stacks are per-thread


# ---------------------------------------------------------------------------
# registry.py
# ---------------------------------------------------------------------------


def test_registry_counter_gauge_histogram():
    reg = MetricsRegistry()
    c = reg.counter("tx_total", "transfers", layer=0)
    c.inc()
    c.inc(2)
    assert reg.counter("tx_total", layer=0) is c  # get-or-create
    reg.gauge("depth", policy="fcfs").set(3.5)
    h = reg.histogram("lat_s", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(5.0)
    snap = reg.snapshot()
    assert snap['tx_total{layer="0"}'] == 3.0
    assert snap['depth{policy="fcfs"}'] == 3.5
    assert snap['lat_s_bucket{le="0.1"}'] == 1.0
    assert snap['lat_s_bucket{le="1.0"}'] == 2.0  # cumulative
    assert snap['lat_s_bucket{le="+Inf"}'] == 3.0
    assert snap["lat_s_count"] == 3.0
    assert snap["lat_s_sum"] == pytest.approx(5.55)


def test_registry_snapshot_diff():
    reg = MetricsRegistry()
    c = reg.counter("n")
    c.inc(5)
    before = reg.snapshot()
    c.inc(3)
    reg.gauge("g").set(2.0)
    d = MetricsRegistry.diff(reg.snapshot(), before)
    assert d["n"] == 3.0 and d["g"] == 2.0


def test_registry_prometheus_text():
    reg = MetricsRegistry()
    reg.counter("tx_total", "number of transfers", op="moe_gmm").inc(4)
    reg.histogram("lat_s", buckets=(1.0,)).observe(0.5)
    text = reg.to_prometheus_text()
    assert "# HELP tx_total number of transfers" in text
    assert "# TYPE tx_total counter" in text
    assert 'tx_total{op="moe_gmm"} 4' in text
    assert "# TYPE lat_s histogram" in text
    assert 'lat_s_bucket{le="+Inf"} 1' in text
    json.loads(reg.to_json())  # parses


def test_registry_type_conflict():
    reg = MetricsRegistry()
    reg.counter("m")
    with pytest.raises(TypeError):
        reg.gauge("m")


def test_kernel_dispatch_counts():
    from repro.kernels.dispatch import resolve
    from repro.obs.registry import REGISTRY

    before = REGISTRY.snapshot()
    resolve("moe_gmm", "auto")
    resolve("moe_gmm", "ref")
    d = MetricsRegistry.diff(REGISTRY.snapshot(), before)
    inc = {k: v for k, v in d.items()
           if k.startswith("kernel_dispatch_total") and v}
    assert sum(inc.values()) == 2
    assert any('backend="ref"' in k for k in inc)
    assert any('backend="pallas"' in k for k in inc)


# ---------------------------------------------------------------------------
# ServerMetrics: TTFT / ITL + rolling windows
# ---------------------------------------------------------------------------


def test_server_metrics_ttft_itl_and_windows():
    mt = ServerMetrics(policy="fcfs", window=8)
    for i in range(20):
        mt.observe_finish(1.0 + i, ttft=0.1 * (i + 1), itl=0.01)
        mt.observe_queue_depth(i)
    s = mt.summary()
    assert s["requests"] == 20  # cumulative, not window-truncated
    assert len(mt.latencies) == 8 == len(mt.ttfts)
    # exact mean over all 20 observations despite the window of 8
    assert s["mean_queue_depth"] == pytest.approx(np.mean(np.arange(20)))
    assert s["ttft_p50"] == pytest.approx(
        np.percentile(np.asarray(mt.ttfts), 50))
    assert s["ttft_p95"] >= s["ttft_p50"] > 0
    assert s["itl_p50"] == pytest.approx(0.01)
    for k in ("ttft_p50", "ttft_p95", "itl_p50", "itl_p95"):
        assert k in s


def test_server_metrics_publish():
    reg = MetricsRegistry()
    mt = ServerMetrics(policy="sjf")
    mt.observe_finish(0.5, ttft=0.1, itl=0.02)
    mt.publish(reg)
    snap = reg.snapshot()
    assert snap['serve_requests{policy="sjf"}'] == 1.0
    assert snap['serve_ttft_p50{policy="sjf"}'] == pytest.approx(0.1)


def test_engine_metrics_per_layer_and_spans():
    m = EngineMetrics()
    m.begin_step(2)
    m.add_flops(1e9)
    m.add_demand_transfers(0, 2, 2048)
    m.add_prefetch_transfers(1, 3, 3072)
    assert m.layer_tx == {0: 2} and m.layer_tx_bytes == {0: 2048}
    assert m.layer_prefetch_tx == {1: 3}
    from repro.core.offload_engine import HardwareProfile

    hw = HardwareProfile()
    assert m.serial_span(hw) > 0
    assert m.overlapped_span(hw, 0, 1) <= m.serial_span(hw, 0, 1) + 1e-12
    # per-layer dicts survive the per-step array drop
    m.drop_step_records(hw)
    assert m.layer_tx == {0: 2}
    reg = MetricsRegistry()
    m.publish(reg, impl="slab")
    assert reg.snapshot()['engine_transfers{impl="slab"}'] == 2.0


# ---------------------------------------------------------------------------
# reconciliation on a real engine run (dict impl, smoke config)
# ---------------------------------------------------------------------------


def test_reconcile_dict_engine(setup):
    cfg, params = setup
    eng = OffloadedMoEEngine(
        cfg, params, capacity=max(cfg.moe_spec.num_experts // 2, 1),
        impl="dict")
    toks = jax.random.randint(jax.random.key(1), (1, 8), 0, cfg.vocab)
    baseline = np.asarray(eng.generate(toks, max_new_tokens=4)["tokens"])

    eng.metrics = EngineMetrics()
    tracer = enable_tracing()
    try:
        res = eng.generate(toks, max_new_tokens=4)
    finally:
        disable_tracing()
    # tracing must not perturb the decode
    assert (np.asarray(res["tokens"]) == baseline).all()

    spans = tracer.spans()
    names = {s.name for s in spans}
    assert {"engine.prefill", "engine.decode_step", "moe.pre",
            "moe.compute"} <= names
    # per-layer attribution exists
    assert any(s.args.get("layer") == 0 for s in spans
               if s.name == "moe.compute")

    report = reconcile(spans, eng.metrics, eng.hw, tolerance=0.5)
    # the invariants the tracing subsystem exists to check:
    assert report.measured_overlap_s >= 0.0
    assert report.modeled_overlapped_s <= report.modeled_serial_s + 1e-12
    # Eq. 3 at measured rates explains the measured step wall
    assert report.ok, report.format_table()
    assert report.serial_agreement_ratio == pytest.approx(1.0, abs=0.5)
    assert report.measured_serial_s > 0
    assert report.unmodeled_s >= 0.0
    moe_rows = [r for r in report.layers if r.layer != OTHER]
    assert len(moe_rows) == len(eng.moe_layer_ids)
    assert all(r.measured_compute_s > 0 for r in moe_rows)
    json.dumps(report.to_json())  # serializable
    assert "Eq.3" in report.format_table()

    # cache instants were aggregated per access with layer attribution
    inst = [i for i in tracer.instants() if i.name == "cache.access"]
    assert inst and all("layer" in i.args for i in inst)


def test_tracing_disabled_leaves_no_buffer(setup):
    cfg, params = setup
    eng = OffloadedMoEEngine(
        cfg, params, capacity=max(cfg.moe_spec.num_experts // 2, 1),
        impl="dict")
    toks = jax.random.randint(jax.random.key(2), (1, 4), 0, cfg.vocab)
    eng.generate(toks, max_new_tokens=2)
    assert get_tracer().spans() == []
    assert get_tracer().instants() == []
