"""Crash-safe serving (PR 9): shared serialization, the write-ahead
request journal + checkpoint/restore (token-identical resumption, warm
cache revival), the invariant-audit watchdog, injected crash faults,
and the queue satellites (O(n) shed paths, KeyError admit, property-
based conservation)."""
import json
import time

import jax
import jax.numpy as jnp
import msgpack
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: deterministic local fallback
    from _hypothesis_shim import given, settings, strategies as st
from repro.configs import get_config
from repro.core.expert_cache import LayerExpertCache
from repro.core.offload_engine import OffloadedMoEEngine
from repro.faults import (
    FaultPlan,
    InjectedCrash,
    install_fault_plan,
    parse_fault_spec,
    uninstall_fault_plan,
)
from repro.models.model import init_params
from repro.obs.registry import MetricsRegistry
from repro.recovery import (
    AuditError,
    RequestJournal,
    Watchdog,
    array_record,
    atomic_write_bytes,
    load_server_checkpoint,
    recover,
    record_array,
    save_server_checkpoint,
)
from repro.recovery.checkpoint import record_request, request_record
from repro.serving import (
    ContinuousBatchingServer,
    OffloadedWaveServer,
    RequestQueue,
    ServeRequest,
)
from repro.serving.metrics import ServerMetrics


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("granite-moe-1b-a400m-smoke")
    params = init_params(jax.random.key(0), cfg, jnp.float32)
    return cfg, params


@pytest.fixture(autouse=True)
def _no_leaked_plan():
    uninstall_fault_plan()
    yield
    uninstall_fault_plan()


def mk_requests(cfg, lens, budgets, *, seed=0, arrivals=None):
    rng = np.random.default_rng(seed)
    return [
        ServeRequest(
            rid=i,
            prompt=rng.integers(0, cfg.vocab, lens[i]).astype(np.int32),
            max_new_tokens=budgets[i],
            arrival_time=0.0 if arrivals is None else arrivals[i],
        )
        for i in range(len(lens))
    ]


# ---------------------------------------------------------------------------
# serialization helpers
# ---------------------------------------------------------------------------


def test_array_record_roundtrip_binary_and_b64():
    for arr in (np.arange(6, dtype=np.int32).reshape(2, 3),
                np.float64(3.5),  # 0-d scalar: shape must survive
                np.zeros(0, np.int64),
                np.random.default_rng(0).normal(size=(3, 2)).astype(np.float32)):
        for binary in (True, False):
            rec = array_record(arr, binary=binary)
            if binary:  # msgpack carries raw bytes
                rec = msgpack.unpackb(msgpack.packb(rec, use_bin_type=True),
                                      raw=False)
            else:  # JSONL carries base64 text
                rec = json.loads(json.dumps(rec))
            out = record_array(rec)
            assert out.dtype == np.asarray(arr).dtype
            assert out.shape == np.asarray(arr).shape
            np.testing.assert_array_equal(out, np.asarray(arr))
    assert record_array(None) is None


def test_atomic_write_replaces_and_leaves_no_tmp(tmp_path):
    p = tmp_path / "x.bin"
    atomic_write_bytes(p, b"first")
    atomic_write_bytes(p, b"second")
    assert p.read_bytes() == b"second"
    assert list(tmp_path.iterdir()) == [p]


def test_request_record_folds_resumed_watermark():
    req = ServeRequest(rid=7, prompt=np.arange(4, dtype=np.int32),
                       max_new_tokens=10, stop_tokens=(3,),
                       arrival_time=1.5, slo=2.0,
                       expert_scores=np.ones((2, 4), np.float32),
                       resumed=np.asarray([5, 6], np.int32))
    rec = request_record(req, binary=False, emitted=[9])
    # watermark is absolute: prior resumed prefix + this incarnation
    assert rec["emitted"] == [5, 6, 9]
    back = record_request(json.loads(json.dumps(rec)))
    np.testing.assert_array_equal(back.prompt, req.prompt)
    np.testing.assert_array_equal(back.resumed, [5, 6, 9])
    assert back.n_resumed == 3 and back.slo == 2.0
    np.testing.assert_array_equal(back.expert_scores, req.expert_scores)


def test_server_checkpoint_roundtrip(tmp_path):
    cache = LayerExpertCache(8, 3, "lfu", layer_id=0)
    cache.access([1, 2, 5])
    mt = ServerMetrics(policy="sjf")
    mt.observe_finish(0.5, ttft=0.1)
    mt.generated_tokens = 42
    reqs = mk_requests(get_config("granite-moe-1b-a400m-smoke"),
                       [4, 5], [6, 7])
    path = tmp_path / "ck.msgpack"
    save_server_checkpoint(
        path, kind="wave", step=3, now=1.25, seed=9, policy="sjf",
        pending=[reqs[0]], inflight=[(reqs[1], [11, 12])], results=[],
        metrics=mt, engine={"cache": [cache.state()], "metrics": {}})
    ck = load_server_checkpoint(path)
    assert (ck["kind"], ck["step"], ck["seed"]) == ("wave", 3, 9)
    mt2 = ServerMetrics.from_state(ck["metrics"])
    assert mt2.generated_tokens == 42 and mt2.requests_finished == 1
    assert list(mt2.ttfts) == [0.1]
    assert ck["inflight"][0]["emitted"] == [11, 12]
    layer = ck["engine"]["cache"][0]
    assert layer["resident"] == [1, 2, 5]
    cache2 = LayerExpertCache(8, 3, "lfu")
    cache2.load_state(layer)
    assert cache2.resident == {1, 2, 5} and cache2.misses == cache.misses
    cache2.load_state(layer, resident=False)  # cold: scores only
    assert cache2.resident == set() and cache2.audit() == []


# ---------------------------------------------------------------------------
# journal: replay, rotation, torn tails
# ---------------------------------------------------------------------------


def _journal_run(tmp_path, cfg):
    """Hand-drive a journal through a tiny serving history."""
    reqs = mk_requests(cfg, [4, 4, 4], [3, 8, 5])
    jr = RequestJournal(tmp_path)
    for r in reqs:
        jr.arrival(r)
        jr.arrival(r)  # idempotent per rid
    jr.admit(0, 0.1)
    jr.watermark({0: [7]}, 0.1)
    jr.watermark({0: [8], 1: [9]}, 0.2)
    from repro.serving.request import ServeResult
    jr.retire(ServeResult(rid=0, tokens=np.asarray([7, 8, 3], np.int32),
                          finish_reason="length", arrival_time=0.0,
                          start_time=0.1, finish_time=0.3),
              plen=4, attained=True, ttft=0.1, itl=0.05)
    jr.shed(reqs[2], expired=True, now=0.3)
    return reqs, jr


def test_journal_replay_rebuilds_state(tmp_path, setup):
    cfg, _ = setup
    reqs, jr = _journal_run(tmp_path, cfg)
    jr.close()
    st_ = recover(tmp_path)
    assert st_ is not None
    # rid 0 retired, rid 2 shed-expired, rid 1 live with its watermark
    assert {r.rid for r in st_.results} == {0, 2}
    assert [r.rid for r in st_.pending] == [1]
    np.testing.assert_array_equal(st_.pending[0].resumed, [9])
    mt = st_.metrics
    assert mt.requests_finished == 1 and mt.requests_expired == 1
    assert mt.generated_tokens == 3  # one wm token per event line
    assert mt.slo_attained == 1
    assert st_.seen_rids == {0, 1, 2}
    assert st_.offered_base == 2
    assert st_.now == pytest.approx(0.3)
    q = st_.build_queue(None)
    assert len(q) == 1 and q.audit() == []


def test_journal_rotation_and_torn_tail(tmp_path, setup):
    cfg, _ = setup
    reqs, jr = _journal_run(tmp_path, cfg)
    mt = ServerMetrics()
    mt.requests_finished, mt.requests_expired = 1, 1
    mt.generated_tokens, mt.slo_attained = 3, 1
    ck = jr.checkpoint_path(5)
    save_server_checkpoint(
        ck, kind="continuous", step=5, now=0.3, seed=0, policy="fcfs",
        pending=[], inflight=[(reqs[1], [9])],  # rid 1 holds a slot
        results=[], metrics=mt)
    jr.rotate(ck, 5, 0.3)
    jr.watermark({1: [13]}, 0.4)  # lands in the fresh segment
    jr.close()
    assert (tmp_path / "journal-0000.jsonl").exists()
    # a crash can tear the last line mid-write
    with open(tmp_path / "journal.jsonl", "a") as f:
        f.write('{"ev": "wm", "toks": {"1": [99')
    st_ = recover(tmp_path)
    # replay = checkpoint + fresh-segment events; torn tail skipped
    assert [r.rid for r in st_.pending] == [1]
    np.testing.assert_array_equal(st_.pending[0].resumed, [9, 13])
    assert st_.step == 5
    assert st_.metrics.generated_tokens == 4
    # crash mid-rotation: active segment already renamed, none reopened
    (tmp_path / "journal.jsonl").rename(tmp_path / "journal-0001.jsonl")
    st2 = recover(tmp_path)
    assert [r.rid for r in st2.pending] == [1]


def test_recover_completes_watermarked_request(tmp_path, setup):
    """A request whose journaled watermark already fills its budget (or
    hits a stop token) retires at replay instead of re-entering
    service (occupy() would reject it)."""
    cfg, _ = setup
    reqs = mk_requests(cfg, [4, 4], [2, 6])
    reqs[1].stop_tokens = (42,)
    jr = RequestJournal(tmp_path)
    for r in reqs:
        jr.arrival(r)
    jr.watermark({0: [7, 8], 1: [5, 42, 6]}, 0.2)  # 0: budget, 1: stop
    jr.close()
    st_ = recover(tmp_path)
    assert st_.pending == []
    by = {r.rid: r for r in st_.results}
    assert by[0].finish_reason == "length"
    np.testing.assert_array_equal(by[0].tokens, [7, 8])
    assert by[1].finish_reason == "stop"
    np.testing.assert_array_equal(by[1].tokens, [5, 42])  # stop-truncated
    assert st_.metrics.requests_finished == 2


def test_recover_empty_dir_returns_none(tmp_path):
    assert recover(tmp_path / "nothing") is None
    (tmp_path / "empty").mkdir()
    assert recover(tmp_path / "empty") is None


# ---------------------------------------------------------------------------
# crash faults
# ---------------------------------------------------------------------------


def test_crash_spec_and_determinism():
    cfg = parse_fault_spec("crash_at=3,seed=1")
    assert cfg.crash_at == 3 and cfg.any_active
    plan = FaultPlan(cfg)
    plan.maybe_crash(); plan.maybe_crash()
    with pytest.raises(InjectedCrash):
        plan.maybe_crash("here")
    assert plan.counters["crash"] == 1
    # rate-based crashes are deterministic per seed
    def crash_point(seed):
        p = FaultPlan(parse_fault_spec(f"crash=0.2,seed={seed}"))
        for i in range(1, 200):
            try:
                p.maybe_crash()
            except InjectedCrash:
                return i
        return None
    assert crash_point(5) is not None
    assert crash_point(5) == crash_point(5)


# ---------------------------------------------------------------------------
# watchdog
# ---------------------------------------------------------------------------


def test_watchdog_green_run_publishes_zero():
    reg = MetricsRegistry()
    q = RequestQueue(mk_requests(get_config("granite-moe-1b-a400m-smoke"),
                                 [4], [3]))
    wd = Watchdog(queue=q, metrics=ServerMetrics(), registry=reg)
    assert wd.check(in_flight=0) == []
    snap = reg.snapshot()
    viol = {k: v for k, v in snap.items()
            if k.startswith("audit_violations_total")}
    assert viol and all(v == 0 for v in viol.values())  # materialized at 0
    assert snap['audit_runs_total'] == 1


def test_watchdog_conservation_violation_raises():
    q = RequestQueue(mk_requests(get_config("granite-moe-1b-a400m-smoke"),
                                 [4, 4], [3, 3]))
    mt = ServerMetrics()
    reg = MetricsRegistry()
    wd = Watchdog(queue=q, metrics=mt, registry=reg)
    wd.check(in_flight=0)
    mt.requests_finished += 1  # a finish the queue never admitted
    with pytest.raises(AuditError) as ei:
        wd.check(in_flight=0)
    assert "conservation" in str(ei.value)
    wd2 = Watchdog(queue=q, metrics=mt, registry=reg, strict=False)
    assert len(wd2.check(in_flight=0)) == 1  # non-strict: report, no raise


def test_watchdog_heals_engine_drift(setup):
    """Dict-impl physical residents outside the cache budget are drift:
    the watchdog resyncs and the re-audit comes back clean."""
    cfg, params = setup
    eng = OffloadedMoEEngine(cfg, params, capacity=2, impl="dict")
    toks = jnp.asarray(np.arange(8)[None] % cfg.vocab)
    eng.generate(toks, max_new_tokens=3)
    layer = eng.resident[0]
    donor = next(iter(layer.values()))
    stale = next(e for e in range(eng.moe_spec.num_experts)
                 if e not in eng.cache.layers[0].resident)
    layer[stale] = donor  # inject residency the cache never granted
    assert any(sev == "drift" for sev, _ in eng.audit())
    reg = MetricsRegistry()
    wd = Watchdog(engine=eng, registry=reg)
    assert wd.check() == []  # healed, not raised
    assert wd.healed_total >= 1
    assert eng.audit() == []


@pytest.mark.recovery
def test_slab_engine_audit_clean_after_serving(setup):
    cfg, params = setup
    eng = OffloadedMoEEngine(cfg, params, capacity=2, impl="slab")
    toks = jnp.asarray(np.arange(6)[None] % cfg.vocab)
    eng.generate(toks, max_new_tokens=4)
    assert eng.audit() == []
    assert eng.resync_slabs() >= 0  # resync on a healthy engine is safe
    assert eng.audit() == []


# ---------------------------------------------------------------------------
# crash -> restore -> replay: token identity (the acceptance gate)
# ---------------------------------------------------------------------------


@pytest.mark.recovery
def test_crash_restore_token_identical_continuous(setup, tmp_path):
    cfg, params = setup
    lens, budgets = [6, 9, 7, 11], [8, 5, 10, 6]
    ref, _ = ContinuousBatchingServer(
        cfg, params, n_slots=2, max_len=32).run(
            RequestQueue(mk_requests(cfg, lens, budgets)))

    srv = ContinuousBatchingServer(cfg, params, n_slots=2, max_len=32)
    jr = RequestJournal(tmp_path)
    install_fault_plan("crash_at=5,seed=0")
    with pytest.raises(InjectedCrash):
        srv.run(RequestQueue(mk_requests(cfg, lens, budgets)),
                journal=jr, checkpoint_every=2)
    jr.close()
    uninstall_fault_plan()

    state = recover(tmp_path)
    assert state is not None and state.kind == "continuous"
    assert state.pending, "crash should leave live requests"
    jr2 = RequestJournal(tmp_path, seen=state.seen_rids)
    results, mt = srv.run(
        state.build_queue(None), state.metrics, journal=jr2,
        checkpoint_every=2, audit_every=2, resume=state)
    jr2.close()
    assert [r.rid for r in results] == [0, 1, 2, 3]
    for a, b in zip(ref, results):
        assert a.finish_reason == b.finish_reason
        np.testing.assert_array_equal(a.tokens, b.tokens)
    # generated tokens are exactly conserved across the crash
    assert mt.generated_tokens == sum(len(r.tokens) for r in ref)


@pytest.mark.recovery
def test_crash_restore_token_identical_wave(setup, tmp_path):
    cfg, params = setup
    lens, budgets = [5, 8, 6, 7], [6, 4, 7, 5]
    ref, _ = OffloadedWaveServer(
        cfg, params, capacity=2, wave_size=2).run(
            RequestQueue(mk_requests(cfg, lens, budgets)))

    srv = OffloadedWaveServer(cfg, params, capacity=2, wave_size=2)
    jr = RequestJournal(tmp_path)
    install_fault_plan("crash_at=11,seed=0")  # mid-generate, engine step
    with pytest.raises(InjectedCrash):
        srv.run(RequestQueue(mk_requests(cfg, lens, budgets)),
                journal=jr, checkpoint_every=1)
    jr.close()
    uninstall_fault_plan()

    state = recover(tmp_path)
    assert state is not None and state.kind == "wave"
    srv2 = OffloadedWaveServer(cfg, params, capacity=2, wave_size=2)
    if state.engine is not None:
        srv2.engine.metrics.load_state(state.engine["metrics"])
        srv2.engine.revive(state.engine["cache"], warm=True)
    jr2 = RequestJournal(tmp_path, seen=state.seen_rids)
    results, mt = srv2.run(
        state.build_queue(None), state.metrics, journal=jr2,
        checkpoint_every=1, audit_every=1, resume=state)
    jr2.close()
    assert [r.rid for r in results] == [0, 1, 2, 3]
    for a, b in zip(ref, results):
        np.testing.assert_array_equal(a.tokens, b.tokens)
    assert srv2.engine.audit() == []


@pytest.mark.recovery
def test_warm_revival_beats_cold_on_demand_transfers(setup):
    """The MELINOE-specific payoff: reviving the checkpointed resident
    set costs prefetch DMA up front but saves demand-miss churn once
    serving resumes."""
    cfg, params = setup
    toks = jnp.asarray(np.arange(10)[None] % cfg.vocab)
    warmup = OffloadedMoEEngine(cfg, params, capacity=2)
    warmup.generate(toks, max_new_tokens=6)
    snap = warmup.cache_state()

    demand = {}
    for mode, warm in (("warm", True), ("cold", False)):
        eng = OffloadedMoEEngine(cfg, params, capacity=2)
        rev = eng.revive(snap, warm=warm)
        assert (rev["loaded"] > 0) == warm
        before = eng.metrics.transfers
        eng.generate(toks, max_new_tokens=6)
        demand[mode] = eng.metrics.transfers - before
        assert eng.audit() == []
    assert demand["warm"] < demand["cold"]


# ---------------------------------------------------------------------------
# queue satellites: O(n) shed paths, admit KeyError, conservation property
# ---------------------------------------------------------------------------


def test_queue_admit_raises_keyerror_with_rid(setup):
    cfg, _ = setup
    reqs = mk_requests(cfg, [4, 4], [3, 3])
    q = RequestQueue(reqs)
    q.admit(reqs[0])
    with pytest.raises(KeyError, match="rid=0"):
        q.admit(reqs[0])  # double admit
    with pytest.raises(KeyError, match="rid=0"):
        q.admit(reqs[0])  # still consistent after the failed admit
    assert q.audit() == []


def test_shed_paths_scale_linearly():
    """Benchmark-backed: shedding half of a 10k-request backlog must be
    an id()-set pass, not an O(n*m) membership rescan. The old
    ``r not in over`` implementation takes seconds here (5k x 10k
    ndarray __eq__ comparisons); the set pass is milliseconds."""
    def build(n):
        return RequestQueue([
            ServeRequest(rid=i, prompt=np.zeros(4, np.int32),
                         max_new_tokens=4, arrival_time=0.0,
                         slo=0.5 if i % 2 else None)
            for i in range(n)
        ], max_pending=n // 2)

    q = build(10_000)
    t0 = time.perf_counter()
    over = q.enforce_bound(now=0.0)
    dt_bound = time.perf_counter() - t0
    assert len(over) == 5_000
    t0 = time.perf_counter()
    expired = q.drop_expired(now=1.0)  # every odd rid's SLO has passed
    dt_exp = time.perf_counter() - t0
    assert len(expired) > 0
    assert q.audit() == []
    assert dt_bound < 1.0 and dt_exp < 1.0, (dt_bound, dt_exp)


@settings(max_examples=25)
@given(st.integers(1, 60), st.integers(1, 8), st.integers(0, 2 ** 31))
def test_queue_conservation_property(n, bound, seed):
    """Under any interleaving of push / admit / expire / bound-shed /
    drain, every request is accounted exactly once:
    arrived == pending + admitted + shed."""
    rng = np.random.default_rng(seed)
    q = RequestQueue(max_pending=bound)
    admitted = 0
    for i in range(n):
        op = rng.integers(4)
        now = float(rng.uniform(0, 2))
        if op == 0:
            q.push(ServeRequest(
                rid=i, prompt=np.zeros(2, np.int32), max_new_tokens=2,
                arrival_time=now,
                slo=float(rng.uniform(0, 1)) if rng.integers(2) else None))
        elif op == 1:
            ready = q.ready(now)
            if ready:
                q.admit(ready[int(rng.integers(len(ready)))])
                admitted += 1
        elif op == 2:
            q.drop_expired(now)
            q.enforce_bound(now)
        else:
            q.drain_shed()
        assert q.audit() == []
    assert q.arrived_total == len(q) + admitted + q.shed_count
    q.drain_shed()
    assert q.audit() == []


# ---------------------------------------------------------------------------
# PR 10 satellites: recovery idempotence, re-offer dedupe, retention
# ---------------------------------------------------------------------------


def _state_fingerprint(st_):
    return {
        "pending": [(r.rid, None if r.resumed is None
                     else [int(t) for t in r.resumed])
                    for r in st_.pending],
        "results": sorted((r.rid, [int(t) for t in r.tokens],
                           r.finish_reason) for r in st_.results),
        "seen": sorted(st_.seen_rids),
        "step": st_.step,
        "now": st_.now,
        "finished": st_.metrics.requests_finished,
        "generated": st_.metrics.generated_tokens,
    }


def test_recover_is_idempotent(tmp_path, setup):
    """recover() is a pure read — the fleet supervisor recovers every
    worker journal on every aggregation pass, so a second recovery of
    the same directory must reproduce the first exactly."""
    cfg, _ = setup
    _journal_run(tmp_path, cfg)[1].close()
    a, b = recover(tmp_path), recover(tmp_path)
    assert a is not None
    assert _state_fingerprint(a) == _state_fingerprint(b)


@settings(max_examples=15)
@given(st.integers(1, 20), st.integers(0, 2 ** 31))
def test_arrival_dedupe_under_duplicate_reoffers(n, seed):
    """Supervisor re-offers can duplicate arbitrarily (a kill between
    journaling an inbox offer and unlinking the file replays it; a
    circuit break re-offers rids a survivor may already hold). Property:
    across two journal generations with duplicated offers, every rid is
    journaled exactly once and recovered exactly once."""
    import tempfile
    from pathlib import Path

    rng = np.random.default_rng(seed)
    rids = [int(r) for r in rng.integers(0, 8, size=n)]
    cut = int(rng.integers(0, n + 1))
    reqs = {rid: ServeRequest(rid=rid, prompt=np.zeros(3, np.int32),
                              max_new_tokens=2) for rid in rids}
    with tempfile.TemporaryDirectory() as d:
        jr = RequestJournal(d)
        for rid in rids[:cut]:  # first incarnation's offers
            jr.arrival(reqs[rid])
        jr.close()
        st1 = recover(Path(d))
        seen = st1.seen_rids if st1 else set()
        jr2 = RequestJournal(d, seen=seen)
        for rid in rids:  # restart: everything re-offered, with dupes
            jr2.arrival(reqs[rid])
        jr2.close()
        st2 = recover(Path(d))
        got = [r.rid for r in st2.pending]
        assert len(got) == len(set(got))
        assert sorted(got) == sorted(set(rids))
        lines = [json.loads(ln) for ln in
                 (Path(d) / "journal.jsonl").read_text().splitlines()]
        assert (sum(1 for ev in lines if ev["ev"] == "arrival")
                == len(set(rids)))


def test_segment_retention_bounded_and_recovery_after_prune(tmp_path, setup):
    """rotate() keeps only the newest ``retain_segments`` rotated
    segments (and the checkpoints they anchor); the checkpoint chain
    carries the pruned history, so recovery is unchanged."""
    cfg, _ = setup
    req = mk_requests(cfg, [4], [16])[0]
    jr = RequestJournal(tmp_path, retain_segments=2)
    jr.arrival(req)
    toks = []
    for k in range(6):
        toks.append(10 + k)
        now = 0.1 * (k + 1)
        jr.watermark({0: [toks[-1]]}, now)
        mt = ServerMetrics()
        mt.generated_tokens = len(toks)
        ck = jr.checkpoint_path(k + 1)
        save_server_checkpoint(
            ck, kind="continuous", step=k + 1, now=now, seed=0,
            policy="fcfs", pending=[], inflight=[(req, list(toks))],
            results=[], metrics=mt)
        jr.rotate(ck, k + 1, now)
    jr.close()
    segs = sorted(p.name for p in tmp_path.glob("journal-*.jsonl"))
    assert len(segs) == 2, segs  # 6 rotations, bounded on disk
    # only checkpoints a retained (or the active) segment anchors live
    cks = sorted(p.name for p in tmp_path.glob("ckpt-*.msgpack"))
    assert 1 <= len(cks) <= 3, cks
    st_ = recover(tmp_path)
    assert [r.rid for r in st_.pending] == [0]
    np.testing.assert_array_equal(st_.pending[0].resumed, toks)
    assert st_.step == 6
    assert st_.metrics.generated_tokens == len(toks)
    # retention off (None): every rotated segment survives
    keep = tmp_path / "keep_all"
    jr2 = RequestJournal(keep, retain_segments=None)
    jr2.arrival(req)
    for k in range(4):
        ck = jr2.checkpoint_path(k + 1)
        save_server_checkpoint(
            ck, kind="continuous", step=k + 1, now=0.0, seed=0,
            policy="fcfs", pending=[req], inflight=[], results=[],
            metrics=ServerMetrics())
        jr2.rotate(ck, k + 1, 0.0)
    jr2.close()
    assert len(list(keep.glob("journal-*.jsonl"))) == 4
