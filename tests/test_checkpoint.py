import jax
import jax.numpy as jnp
import numpy as np

from repro.training.checkpoint import load_checkpoint, save_checkpoint


def test_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.ones((4,), jnp.bfloat16), "c": jnp.asarray(3, jnp.int32)},
    }
    p = tmp_path / "ck.msgpack"
    save_checkpoint(p, tree, step=7, metadata={"arch": "x"})
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, step, meta = load_checkpoint(p, like)
    assert step == 7 and meta["arch"] == "x"
    for k in ("a",):
        np.testing.assert_array_equal(np.asarray(restored[k]), np.asarray(tree[k]))
    assert restored["nested"]["b"].dtype == jnp.bfloat16
    assert int(restored["nested"]["c"]) == 3
