"""Config registry: exact assigned hyper-parameters + input specs."""
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED, SHAPES, get_config, list_archs
from repro.launch.specs import decode_window_override, input_specs

EXPECT = {
    "musicgen-medium": dict(L=48, d=1536, H=24, kv=24, vocab=2048, family="audio"),
    "gemma2-27b": dict(L=46, d=4608, H=32, kv=16, vocab=256000, family="dense"),
    "granite-moe-1b-a400m": dict(L=24, d=1024, H=16, kv=8, vocab=49155, family="moe",
                                 E=32, K=8),
    "stablelm-12b": dict(L=40, d=5120, H=32, kv=8, vocab=100352, family="dense"),
    "zamba2-7b": dict(L=81, d=3584, vocab=32000, family="hybrid"),
    "command-r-plus-104b": dict(L=64, d=12288, H=96, kv=8, vocab=256000, family="dense"),
    "deepseek-moe-16b": dict(L=28, d=2048, H=16, kv=16, vocab=102400, family="moe",
                             E=64, K=6),
    "internvl2-76b": dict(L=80, d=8192, H=64, kv=8, vocab=128256, family="vlm"),
    "qwen3-4b": dict(L=36, d=2560, H=32, kv=8, vocab=151936, family="dense"),
    "mamba2-130m": dict(L=24, d=768, vocab=50280, family="ssm"),
}


@pytest.mark.parametrize("arch", sorted(EXPECT))
def test_assigned_configs_exact(arch):
    cfg = get_config(arch)
    e = EXPECT[arch]
    assert cfg.n_layers == e["L"]
    assert cfg.d_model == e["d"]
    assert cfg.vocab == e["vocab"]
    assert cfg.family == e["family"]
    if "H" in e:
        attn = next(b.attn for b in cfg.block_defs.values() if b.attn is not None)
        assert attn.n_heads == e["H"] and attn.n_kv_heads == e["kv"]
    if "E" in e:
        assert cfg.moe_spec.num_experts == e["E"]
        assert cfg.moe_spec.top_k == e["K"]
    assert cfg.source  # every config cites its source


def test_all_assigned_present():
    assert set(ASSIGNED) <= set(list_archs())
    assert len(ASSIGNED) == 10


@pytest.mark.parametrize("shape", sorted(SHAPES))
def test_input_specs_shapes(shape):
    cfg = get_config("granite-moe-1b-a400m")
    sh = SHAPES[shape]
    specs = input_specs(cfg, sh)
    if sh.mode == "train":
        assert specs["tokens"].shape == (sh.global_batch, sh.seq_len)
        assert specs["labels"].shape == (sh.global_batch, sh.seq_len)
    elif sh.mode == "prefill":
        assert specs["tokens"].shape == (sh.global_batch, sh.seq_len)
    else:
        assert specs["tokens"].shape == (sh.global_batch, 1)
        assert "cache" in specs


def test_long_context_policy():
    assert decode_window_override(get_config("mamba2-130m"), SHAPES["long_500k"]) is None
    assert decode_window_override(get_config("command-r-plus-104b"),
                                  SHAPES["long_500k"]) == 8192
    assert decode_window_override(get_config("command-r-plus-104b"),
                                  SHAPES["decode_32k"]) is None


def test_long_500k_cache_is_bounded():
    """The 500k decode cache must use the ring-buffer window, not 500k slots."""
    import jax

    cfg = get_config("qwen3-4b")
    specs = input_specs(cfg, SHAPES["long_500k"])
    kv_leaves = [
        l for l in jax.tree.leaves(specs["cache"]) if getattr(l, "ndim", 0) == 5
    ]
    assert kv_leaves and all(l.shape[2] == cfg.long_context_window for l in kv_leaves)


def test_melinoe_capacity_default_quarter():
    cfg = get_config("granite-moe-1b-a400m")
    assert cfg.melinoe_cache_capacity() == 8  # E/4 = 32/4
    assert get_config("olmoe").melinoe_cache_capacity() == 16  # paper C=16
