"""AdamW + schedule + trainable-mask freezing."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.training.optim import (
    OptConfig,
    adamw_update,
    global_norm,
    init_opt_state,
    schedule,
)


def test_schedule_warmup_and_decay():
    cfg = OptConfig(peak_lr=1e-3, total_steps=100, warmup_ratio=0.1)
    lrs = [float(schedule(jnp.asarray(s), cfg)) for s in range(101)]
    assert lrs[0] < lrs[5] < lrs[10]
    np.testing.assert_allclose(lrs[10], 1e-3, rtol=1e-5)
    assert lrs[50] < lrs[10] and lrs[100] < 1e-6 + 1e-9


def test_adamw_converges_on_quadratic():
    cfg = OptConfig(peak_lr=0.1, total_steps=200, warmup_ratio=0.01, clip_norm=None)
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = init_opt_state(params)
    target = jnp.asarray([1.0, 1.0])
    for _ in range(200):
        g = {"w": 2 * (params["w"] - target)}
        params, opt, _ = adamw_update(g, opt, params, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=1e-2)


def test_mask_freezes_leaves():
    cfg = OptConfig(peak_lr=0.1, total_steps=10)
    params = {"a": jnp.ones(3), "b": jnp.ones(3)}
    opt = init_opt_state(params)
    g = {"a": jnp.ones(3), "b": jnp.ones(3)}
    p2, opt2, _ = adamw_update(g, opt, params, cfg, mask={"a": True, "b": False})
    assert float(jnp.abs(p2["a"] - params["a"]).sum()) > 0
    assert float(jnp.abs(p2["b"] - params["b"]).sum()) == 0
    assert float(jnp.abs(opt2["mu"]["b"]).sum()) == 0


def test_clipping_bounds_update():
    cfg = OptConfig(peak_lr=0.1, total_steps=10, clip_norm=1.0)
    params = {"w": jnp.zeros(4)}
    opt = init_opt_state(params)
    g = {"w": jnp.full(4, 1e6)}
    p2, _, _ = adamw_update(g, opt, params, cfg)
    assert float(global_norm(jax.tree.map(lambda a, b: a - b, p2, params))) < 1.0
