"""MELINOE fine-tuning integration: a few steps on a tiny MoE must reduce
the cache-simulation loss (routing concentrates) without NLL blowup, and
the routing trace must show fewer hard-cache transfers (paper Table 3
mechanism at micro scale)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow

from repro.configs import get_config
from repro.core.cache_sim import hard_cache_misses, topk_request
from repro.core.lora import extract_base_routers, lora_scale, melinoe_trainable_mask
from repro.data.synthetic import ClusterLM, SyntheticConfig
from repro.launch.steps import build_finetune_step
from repro.models import Runtime, apply_model, init_params
from repro.models.model import MelinoeRun
from repro.training.optim import OptConfig, init_opt_state


@pytest.fixture(scope="module")
def finetuned():
    from util import melinoe_test_config
    from repro.training.trainer import pretrain

    cfg = melinoe_test_config()  # 8 experts top-2, C=2
    rt = Runtime()
    lm = ClusterLM(SyntheticConfig(vocab=cfg.vocab, seq_len=32, n_clusters=4))
    # brief pretrain first: MELINOE *amplifies* per-sequence expert
    # preferences, so the held-out transfer reduction needs a base model
    # with real (cluster-driven) routing structure — from a random init
    # the margin sits at the noise floor
    params = pretrain(cfg, lm.batches(4, seed=1), steps=16, log_every=100,
                      verbose=False).params
    it = lm.batches(4, seed=2)
    from repro.core.lora import init_lora

    lora = init_lora(jax.random.key(1), cfg, cfg.melinoe)
    mask = melinoe_trainable_mask(params)
    base_routers = jax.tree.map(jnp.copy, extract_base_routers(params, cfg))
    opt = init_opt_state((params, lora))
    step = jax.jit(build_finetune_step(cfg, rt, OptConfig(peak_lr=3e-3, total_steps=30),
                                       mask))
    hist = []
    p, l = params, lora
    for i in range(16):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        p, l, opt, metrics = step(p, l, opt, batch, base_routers)
        hist.append({k: float(v) for k, v in metrics.items()})
    return cfg, params, p, l, hist, lm


def test_cs_loss_decreases(finetuned):
    cfg, base, ft, lora, hist, lm = finetuned
    assert hist[-1]["cs_loss"] < hist[0]["cs_loss"]


def test_nll_does_not_blow_up(finetuned):
    cfg, base, ft, lora, hist, lm = finetuned
    assert hist[-1]["nll"] < hist[0]["nll"] * 1.2


def test_frozen_weights_untouched(finetuned):
    cfg, base, ft, lora, hist, lm = finetuned
    # attention weights are frozen under the melinoe partition
    b = base["groups"]["g0"]["p0"]["mixer"]["wq"]
    f = ft["groups"]["g0"]["p0"]["mixer"]["wq"]
    np.testing.assert_array_equal(np.asarray(b), np.asarray(f))
    # router weights did move
    br = base["groups"]["g0"]["p0"]["ffn"]["router"]
    fr = ft["groups"]["g0"]["p0"]["ffn"]["router"]
    assert float(jnp.abs(br - fr).max()) > 0


def test_hard_transfers_reduced_on_heldout(finetuned):
    cfg, base, ft, lora, hist, lm = finetuned
    rt = Runtime()
    toks = jnp.asarray(next(lm.batches(4, seed=77))["tokens"])
    C = cfg.melinoe_cache_capacity()
    K = cfg.moe_spec.top_k

    def transfers(params, lora_=None):
        _, aux = apply_model(params, cfg, toks, rt, collect_probs=True,
                             lora=lora_, lora_scale=lora_scale(cfg.melinoe))
        total = 0.0
        for p in aux["probs"]:  # (R, B, T, E)
            R, B, T, E = p.shape
            for r in range(R):
                for b in range(B):
                    rq = topk_request(p[r, b], K, "hard")
                    total += float(hard_cache_misses(rq, 0.9, C))
        return total

    t_base = transfers(base)
    t_ft = transfers(ft, lora)
    assert t_ft < t_base, (t_base, t_ft)
