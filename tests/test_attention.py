"""Blockwise flash attention vs naive reference; decode ring buffer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AttnSpec
from repro.models.attention import (
    _project_qkv,
    attend_full,
    cache_from_prefill,
    decode_attend,
    flash_attention,
    init_attn,
)


def naive(q, k, v, spec, window=None):
    B, T, Hq, hd = q.shape
    G = Hq // spec.n_kv_heads
    qg = q.reshape(B, T, spec.n_kv_heads, G, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k) * hd**-0.5
    if spec.attn_softcap:
        s = jnp.tanh(s / spec.attn_softcap) * spec.attn_softcap
    i = jnp.arange(T)
    mask = i[None] <= i[:, None]
    if window:
        mask &= i[None] > i[:, None] - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhgqk,bkhd->bqhgd", p, v).reshape(B, T, Hq, hd)


@pytest.mark.parametrize(
    "T,window,cap,kv,bq,bk",
    [
        (11, None, None, 2, 512, 1024),
        (64, 16, None, 2, 16, 16),
        (200, 32, 50.0, 1, 37, 53),
        (300, None, 30.0, 4, 64, 128),
        (128, 200, None, 2, 32, 32),  # window larger than T
    ],
)
def test_flash_vs_naive(T, window, cap, kv, bq, bk):
    spec = AttnSpec(n_heads=4, n_kv_heads=kv, head_dim=16, attn_softcap=cap)
    params = init_attn(jax.random.key(0), 64, spec, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, T, 64))
    pos = jnp.broadcast_to(jnp.arange(T), (2, T))
    q, k, v = _project_qkv(params, spec, x, pos)
    out = flash_attention(q, k, v, spec, window=window, bq=bq, bk=bk)
    ref = naive(q, k, v, spec, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=1e-4)


def test_qk_norm_changes_output():
    a = AttnSpec(n_heads=2, n_kv_heads=2, head_dim=16, qk_norm=False)
    b = AttnSpec(n_heads=2, n_kv_heads=2, head_dim=16, qk_norm=True)
    pa = init_attn(jax.random.key(0), 32, a, jnp.float32)
    pb = init_attn(jax.random.key(0), 32, b, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 8, 32))
    pos = jnp.broadcast_to(jnp.arange(8), (1, 8))
    ya = attend_full(pa, a, x, pos, None)
    yb = attend_full(pb, b, x, pos, None)
    assert float(jnp.abs(ya - yb).max()) > 1e-4


def test_decode_matches_naive_and_ring_buffer_wraps():
    spec = AttnSpec(n_heads=4, n_kv_heads=2, head_dim=16, window=8)
    d = 64
    params = init_attn(jax.random.key(0), d, spec, jnp.float32)
    B, T = 1, 20
    x = jax.random.normal(jax.random.key(1), (B, T, d))
    pos = jnp.broadcast_to(jnp.arange(T), (B, T))
    ref = attend_full(params, spec, x, pos, spec.window) @ jnp.eye(d)  # full path
    # windowed ring cache with only 8 slots
    Tp = 4
    _, (k, v) = attend_full(params, spec, x[:, :Tp], pos[:, :Tp], spec.window,
                            return_kv=True)
    cache = cache_from_prefill(k, v, spec, 8)
    outs = []
    for t in range(Tp, T):
        o, cache = decode_attend(params, spec, x[:, t : t + 1], cache,
                                 jnp.asarray(t, jnp.int32), spec.window)
        outs.append(o)
    dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(ref[:, Tp:]), atol=1e-4, rtol=1e-4
    )
    assert cache.k.shape[1] == 8  # never grew


def test_prefill_ring_compression_keeps_last_window():
    spec = AttnSpec(n_heads=2, n_kv_heads=2, head_dim=8, window=4)
    params = init_attn(jax.random.key(0), 16, spec, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 10, 16))
    pos = jnp.broadcast_to(jnp.arange(10), (1, 10))
    _, (k, v) = attend_full(params, spec, x, pos, spec.window, return_kv=True)
    cache = cache_from_prefill(k, v, spec, 4)
    assert cache.slot_pos.shape == (1, 4)  # per-row positions
    kept = sorted(int(p) for p in np.asarray(cache.slot_pos[0]))
    assert kept == [6, 7, 8, 9]
    # slot alignment: position p lives at slot p % W
    for p in kept:
        assert int(cache.slot_pos[0, p % 4]) == p


def test_decode_per_row_positions_match_lockstep():
    """A (B,) position vector must reproduce per-row lockstep decoding:
    row i of a staggered batch == the same sequence decoded alone."""
    spec = AttnSpec(n_heads=4, n_kv_heads=2, head_dim=16)
    d = 64
    params = init_attn(jax.random.key(0), d, spec, jnp.float32)
    T, W = 12, 16
    xs = [jax.random.normal(jax.random.key(i + 1), (1, T, d)) for i in range(2)]
    pos = jnp.broadcast_to(jnp.arange(T), (1, T))
    # reference: each row prefilled + decoded alone, in lockstep
    refs, caches, starts = [], [], [3, 7]
    for x, tp in zip(xs, starts):
        _, (k, v) = attend_full(params, spec, x[:, :tp], pos[:, :tp], None,
                                return_kv=True)
        caches.append(cache_from_prefill(k, v, spec, W))
        outs = []
        c = caches[-1]
        for t in range(tp, T):
            o, c = decode_attend(params, spec, x[:, t : t + 1], c,
                                 jnp.asarray(t, jnp.int32), None)
            outs.append(o)
        refs.append(jnp.concatenate(outs, 1))
    # batched: rows start at different positions, advanced by a pos vector
    cache = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=0), *caches)
    p = jnp.asarray(starts, jnp.int32)
    got = [[], []]
    for step in range(T - max(starts)):
        x_step = jnp.concatenate(
            [xs[i][:, starts[i] + step : starts[i] + step + 1] for i in range(2)], 0
        )
        o, cache = decode_attend(params, spec, x_step, cache, p, None)
        for i in range(2):
            got[i].append(o[i : i + 1])
        p = p + 1
    for i in range(2):
        n = len(got[i])
        np.testing.assert_allclose(
            np.asarray(jnp.concatenate(got[i], 1)),
            np.asarray(refs[i][:, :n]), atol=1e-5, rtol=1e-5,
        )
