"""Resilience subsystem (PR 8): deterministic fault plans, bounded
fetch retry/backoff, little-expert degraded mode, SLO load shedding and
deadline retirement in both servers, and the zero-cost-when-disabled
guarantee (a degraded-mode-capable engine with faults off is bit-for-bit
the plain slab engine)."""
import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.offload_engine import OffloadedMoEEngine
from repro.models.model import init_params
from repro.data.synthetic import ClusterLM, SyntheticConfig
from repro.faults import (
    NAIVE_POLICY,
    NULL_FAULT_PLAN,
    FaultConfig,
    FaultPlan,
    FetchPolicy,
    get_fault_plan,
    install_fault_plan,
    parse_fault_spec,
    uninstall_fault_plan,
)
from repro.serving import (
    ContinuousBatchingServer,
    OffloadedWaveServer,
    RequestQueue,
    ServeRequest,
    TrafficConfig,
    synthesize_workload,
)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("granite-moe-1b-a400m-smoke")
    params = init_params(jax.random.key(0), cfg, jnp.float32)
    toks = jax.random.randint(jax.random.key(1), (1, 12), 0, cfg.vocab)
    return cfg, params, toks


@pytest.fixture(autouse=True)
def _no_leaked_plan():
    """Every test starts and ends with injection disabled."""
    uninstall_fault_plan()
    yield
    uninstall_fault_plan()


def workload(cfg, n=8, *, rate=100.0, slo=None, quality=1.0, seed=0,
             max_new=(3, 6)):
    lm = ClusterLM(SyntheticConfig(vocab=cfg.vocab, seq_len=24,
                                   n_clusters=4, seed=seed))
    tcfg = TrafficConfig(n_requests=n, arrival="poisson", rate=rate,
                         prompt_len=(4, 8), max_new_tokens=max_new,
                         slo=slo, quality=quality, seed=seed + 1)
    return synthesize_workload(lm, tcfg)


# ---------------------------------------------------------------------------
# Fault plan: spec grammar, determinism, installation
# ---------------------------------------------------------------------------


def test_parse_fault_spec_grammar():
    cfg = parse_fault_spec("fail=0.1,spike=0.05:2e-3,storm=0.02:0.5,"
                           "step_delay=0.01:1e-3,burst=0.9,seed=7")
    assert cfg.fetch_fail_rate == 0.1
    assert (cfg.spike_rate, cfg.spike_s) == (0.05, 2e-3)
    assert (cfg.storm_rate, cfg.storm_frac) == (0.02, 0.5)
    assert (cfg.step_delay_rate, cfg.step_delay_s) == (0.01, 1e-3)
    assert cfg.burst_compress == 0.9
    assert cfg.seed == 7 and cfg.any_active
    with pytest.raises(ValueError):
        parse_fault_spec("no_such_knob=1")


def test_fault_plan_deterministic_per_seed():
    draws = lambda p: [p.fetch_fails() for _ in range(64)]
    a = draws(FaultPlan(FaultConfig(seed=3, fetch_fail_rate=0.5)))
    b = draws(FaultPlan(FaultConfig(seed=3, fetch_fail_rate=0.5)))
    c = draws(FaultPlan(FaultConfig(seed=4, fetch_fail_rate=0.5)))
    assert a == b and a != c and any(a) and not all(a)


def test_install_and_env_plan(monkeypatch):
    assert get_fault_plan() is NULL_FAULT_PLAN
    assert not get_fault_plan().enabled
    plan = install_fault_plan("fail=0.5,seed=1")
    assert get_fault_plan() is plan and plan.enabled
    uninstall_fault_plan()
    assert get_fault_plan() is NULL_FAULT_PLAN
    # env opt-in mirrors enable_tracing's REPRO_TRACE
    monkeypatch.setenv("REPRO_FAULTS", "spike=1.0:1e-3,seed=2")
    from repro.faults import fault_plan_from_env

    env_plan = fault_plan_from_env()
    assert env_plan is not None and get_fault_plan() is env_plan
    assert env_plan.transfer_spike() == pytest.approx(1e-3)


def test_null_plan_is_benign():
    p = NULL_FAULT_PLAN
    assert not p.fetch_fails() and p.transfer_spike() == 0.0
    assert p.eviction_storm() == 0.0 and p.step_delay() == 0.0
    assert p.storm_victims([1, 2, 3], 0.5) == []
    reqs = [ServeRequest(rid=0, prompt=np.zeros(2, np.int32),
                         arrival_time=1.0)]
    p.compress_arrivals(reqs)
    assert reqs[0].arrival_time == 1.0


def test_burst_compression_preserves_order():
    plan = FaultPlan(FaultConfig(burst_compress=0.5, burst_window=4))
    reqs = [ServeRequest(rid=i, prompt=np.zeros(2, np.int32),
                         arrival_time=float(i)) for i in range(8)]
    plan.compress_arrivals(reqs)
    times = [r.arrival_time for r in reqs]
    assert times == sorted(times)
    assert times[0] == 0.0 and times[3] == pytest.approx(1.5)  # window 1
    assert times[4] == 4.0 and times[7] == pytest.approx(5.5)  # window 2


def test_fetch_policy_backoff_and_budget():
    pol = FetchPolicy(max_retries=2, backoff_base_s=1e-4,
                      backoff_mult=2.0, backoff_cap_s=3e-4)
    assert pol.backoff(0) == pytest.approx(1e-4)
    assert pol.backoff(1) == pytest.approx(2e-4)
    assert pol.backoff(5) == pytest.approx(3e-4)  # capped
    assert pol.attempts_allowed(2, 0.0) and not pol.attempts_allowed(3, 0.0)
    tight = FetchPolicy(fetch_deadline_s=1e-3)
    assert not tight.attempts_allowed(1, 2e-3)  # deadline spent
    assert NAIVE_POLICY.attempts_allowed(999, 1e9)  # unbounded...
    assert not NAIVE_POLICY.attempts_allowed(NAIVE_POLICY.hard_cap, 0.0)


# ---------------------------------------------------------------------------
# Engine: degraded mode, retries, deadline, zero-cost parity
# ---------------------------------------------------------------------------


def test_little_engine_bit_for_bit_with_faults_off(setup):
    """The tentpole's acceptance anchor: building the little bank and
    threading the resilience hooks costs nothing when disabled —
    identical tokens AND identical transfer accounting."""
    cfg, params, toks = setup
    plain = OffloadedMoEEngine(cfg, params, capacity=2, impl="slab")
    little = OffloadedMoEEngine(cfg, params, capacity=2, impl="slab",
                                little_experts=True)
    rp = plain.generate(toks, max_new_tokens=5)
    rl = little.generate(toks, max_new_tokens=5)
    assert bool(jnp.all(rp["tokens"] == rl["tokens"]))
    assert rp["metrics"].transfers == rl["metrics"].transfers
    assert rl["metrics"].degraded_uses == 0
    assert rl["metrics"].fault_delay_s == 0.0


@pytest.mark.parametrize("impl", ["slab", "dict"])
def test_total_fetch_failure_fully_degrades(setup, impl):
    """100% transient fetch failure: every MoE layer falls back to the
    little experts, no transfer ever lands, and the run completes."""
    cfg, params, toks = setup
    install_fault_plan("fail=1.0,seed=0")
    eng = OffloadedMoEEngine(cfg, params, capacity=2, impl=impl,
                             little_experts=True)
    res = eng.generate(toks, max_new_tokens=4)
    m = res["metrics"]
    assert res["tokens"].shape[-1] == 4
    assert m.transfers == 0 and m.degraded_uses > 0
    assert m.fetch_failures > 0 and m.fault_delay_s > 0.0
    assert eng.little.substitutions >= len(eng.moe_layer_ids)


def test_naive_retry_stays_exact_under_faults(setup):
    """Without a little bank a demand fetch cannot degrade: it retries
    until success, charging the stalls — tokens are unchanged."""
    cfg, params, toks = setup
    base = OffloadedMoEEngine(cfg, params, capacity=2, impl="slab")
    ref = base.generate(toks, max_new_tokens=4)
    install_fault_plan("fail=0.3,seed=5")
    eng = OffloadedMoEEngine(cfg, params, capacity=2, impl="slab",
                             fetch_policy=NAIVE_POLICY)
    res = eng.generate(toks, max_new_tokens=4)
    assert bool(jnp.all(res["tokens"] == ref["tokens"]))
    assert res["metrics"].transfers == ref["metrics"].transfers
    assert res["metrics"].fetch_failures > 0
    assert res["metrics"].fault_delay_s > 0.0
    assert res["metrics"].degraded_uses == 0


def test_quality_dial_zero_substitutes_everything(setup):
    """quality=0.0 degrades every miss by choice — no faults needed, no
    transfers charged; quality=1.0 is the exact path."""
    cfg, params, toks = setup
    eng = OffloadedMoEEngine(cfg, params, capacity=2, impl="slab",
                             little_experts=True)
    res = eng.generate(toks, max_new_tokens=4, quality=0.0)
    assert res["metrics"].transfers == 0
    assert res["metrics"].degraded_uses > 0
    assert res["metrics"].fault_delay_s == 0.0  # degrade-by-choice is free


def test_degraded_output_close_to_exact(setup):
    """The little experts are rank-truncated distillates of the real
    weights: a fully degraded decode should stay in the neighborhood of
    the exact one (same model, lossy experts), not produce garbage."""
    cfg, params, toks = setup
    exact = OffloadedMoEEngine(cfg, params, capacity=2, impl="slab")
    re_ = exact.generate(toks, max_new_tokens=4)
    deg = OffloadedMoEEngine(cfg, params, capacity=2, impl="slab",
                             little_experts=True,
                             little_rank=cfg.d_model)  # full rank
    rd = deg.generate(toks, max_new_tokens=4, quality=0.0)
    # at full rank the SVD truncation is lossless => identical tokens
    assert bool(jnp.all(re_["tokens"] == rd["tokens"]))


def test_engine_deadline_stops_early(setup):
    cfg, params, toks = setup
    eng = OffloadedMoEEngine(cfg, params, capacity=2, impl="slab",
                             little_experts=True)
    res = eng.generate(toks, max_new_tokens=16, deadline_s=1e-9)
    assert res["stopped_early"]
    assert res["tokens"].shape[-1] < 16


def test_eviction_storm_forces_refetches(setup):
    cfg, params, toks = setup
    base = OffloadedMoEEngine(cfg, params, capacity=2, impl="slab")
    ref = base.generate(toks, max_new_tokens=4)
    install_fault_plan("storm=1.0:1.0,seed=2")  # every step drops all
    eng = OffloadedMoEEngine(cfg, params, capacity=2, impl="slab",
                             fetch_policy=NAIVE_POLICY)
    res = eng.generate(toks, max_new_tokens=4)
    assert bool(jnp.all(res["tokens"] == ref["tokens"]))  # still exact
    assert res["metrics"].transfers > ref["metrics"].transfers
    assert eng.cache.stats().evictions > base.cache.stats().evictions


def test_overlapped_clock_never_beats_serial_under_faults(setup):
    cfg, params, toks = setup
    install_fault_plan("fail=0.2,spike=0.1:2e-3,seed=9")
    eng = OffloadedMoEEngine(cfg, params, capacity=2, impl="slab",
                             little_experts=True)
    eng.generate(toks, max_new_tokens=4)
    m = eng.metrics
    assert m.modeled_time_overlapped(eng.hw) <= m.modeled_time(eng.hw) + 1e-12


# ---------------------------------------------------------------------------
# Servers: SLO shedding, deadline retirement, counters
# ---------------------------------------------------------------------------


@pytest.mark.chaos
def test_wave_server_chaos_completes_and_accounts(setup):
    """10% failure + spikes + storms: every offered request resolves to
    exactly one result (finished, degraded, deadline-cut, or shed) and
    the counters partition the offered set."""
    cfg, params, _ = setup
    install_fault_plan("fail=0.1,spike=0.05:2e-3,storm=0.02:0.5,seed=11")
    reqs = workload(cfg, n=10, rate=200.0, slo=0.05)
    srv = OffloadedWaveServer(cfg, params, capacity=2, wave_size=2,
                              little_experts=True, max_backlog=4)
    res, mt = srv.run(RequestQueue(reqs))
    assert len(res) == 10
    assert mt.requests_offered == 10
    finished = sum(1 for r in res if r.finish_reason in
                   ("stop", "length", "deadline"))
    shed = sum(1 for r in res if r.finish_reason == "shed")
    assert finished == mt.requests_finished
    assert shed == mt.requests_shed + mt.requests_expired
    assert mt.slo_attained <= mt.requests_finished
    assert 0.0 <= mt.slo_attainment <= 1.0


@pytest.mark.chaos
def test_wave_server_deadline_and_degraded_flags(setup):
    cfg, params, _ = setup
    install_fault_plan("fail=1.0,seed=3")
    reqs = workload(cfg, n=4, rate=1e9, slo=10.0, max_new=(4, 4))
    srv = OffloadedWaveServer(cfg, params, capacity=2, wave_size=2,
                              little_experts=True)
    res, mt = srv.run(RequestQueue(reqs))
    served = [r for r in res if r.finish_reason != "shed"]
    assert served and all(r.degraded for r in served)
    assert mt.degraded_requests == len(served)


def test_wave_server_sheds_expired_requests(setup):
    """A request whose SLO lapses while queued is shed, not served."""
    cfg, params, _ = setup
    reqs = workload(cfg, n=6, rate=1e6, slo=1e-9)
    srv = OffloadedWaveServer(cfg, params, capacity=2, wave_size=2)
    res, mt = srv.run(RequestQueue(reqs))
    assert len(res) == 6
    # the first wave is admitted before its deadline is checked; later
    # arrivals expire on the queue once the wave's modeled time passes
    assert mt.requests_expired > 0
    assert all(r.finish_reason == "shed" for r in res
               if r.rid in {x.rid for x in res[-mt.requests_expired:]})


def test_continuous_server_deadline_retires(setup):
    cfg, params, _ = setup
    reqs = workload(cfg, n=4, rate=1e9, slo=1e-6, max_new=(8, 8))
    srv = ContinuousBatchingServer(cfg, params, n_slots=2, max_len=48)
    res, mt = srv.run(RequestQueue(reqs))
    assert len(res) == 4
    assert mt.deadline_retired + mt.requests_expired + mt.requests_shed > 0
    assert mt.slo_attained == 0
    for r in res:
        assert r.finish_reason in ("stop", "length", "deadline", "shed")


def test_continuous_server_best_effort_unaffected(setup):
    """slo=None requests are never shed or deadline-cut and always
    count as attained."""
    cfg, params, _ = setup
    reqs = workload(cfg, n=4, rate=100.0, slo=None)
    srv = ContinuousBatchingServer(cfg, params, n_slots=2, max_len=48)
    res, mt = srv.run(RequestQueue(reqs))
    assert mt.requests_shed == mt.requests_expired == 0
    assert mt.deadline_retired == 0
    assert mt.slo_attained == 4 and mt.slo_attainment == 1.0


@pytest.mark.chaos
def test_fault_counters_reach_prometheus(setup):
    from repro.obs.registry import MetricsRegistry

    cfg, params, toks = setup
    install_fault_plan("fail=0.5,spike=0.2:1e-3,seed=13")
    eng = OffloadedMoEEngine(cfg, params, capacity=2, impl="slab",
                             little_experts=True)
    eng.generate(toks, max_new_tokens=3)
    reg = MetricsRegistry()
    get_fault_plan().publish(reg)
    eng.metrics.publish(reg)
    text = reg.to_prometheus_text()
    assert "fault_injected_total" in text
    assert "engine_fault_delay_s" in text or "fault_delay_s" in text
    assert "degraded_uses" in text


def test_fetch_policy_backoff_jitter_seeded_and_bounded():
    """PR 10 satellite: optional deterministic jitter decorrelates
    backoff across callers (salt = worker/expert index) while staying a
    pure function of (seed, salt, attempt) — no RNG state, so restart
    schedules are reproducible. NAIVE keeps the un-jittered ladder."""
    kw = dict(backoff_base_s=0.1, backoff_mult=2.0, backoff_cap_s=1.0)
    plain = FetchPolicy(**kw)
    jit = FetchPolicy(**kw, jitter_frac=0.5, seed=3)
    for attempt in range(8):
        b = plain.backoff(attempt)
        assert b == min(0.1 * 2.0 ** attempt, 1.0)  # ladder unchanged
        for salt in range(4):
            j = jit.backoff(attempt, salt=salt)
            assert b * 0.5 < j <= b  # bounded: base*(1-frac) < j <= base
            assert j == jit.backoff(attempt, salt=salt)  # deterministic
    # distinct salts decorrelate; distinct seeds reshuffle
    assert len({jit.backoff(3, salt=s) for s in range(8)}) > 1
    other = FetchPolicy(**kw, jitter_frac=0.5, seed=4)
    assert other.backoff(3, salt=0) != jit.backoff(3, salt=0)
    assert NAIVE_POLICY.jitter_frac == 0.0
    assert NAIVE_POLICY.backoff(5) == pytest.approx(min(
        NAIVE_POLICY.backoff_base_s * NAIVE_POLICY.backoff_mult ** 5,
        NAIVE_POLICY.backoff_cap_s))
