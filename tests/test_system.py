"""End-to-end system behaviour: the full MELINOE pipeline at micro scale —
pretrain -> fine-tune -> predictor -> offloaded serving — reproducing the
paper's qualitative claims (transfer reduction, quality retention)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow

from repro.configs import get_config
from repro.core.offload_engine import OffloadedMoEEngine
from repro.core.lora import lora_scale
from repro.data.synthetic import ClusterLM, SyntheticConfig, eval_batches
from repro.training.trainer import eval_nll, melinoe_finetune, merge_lora, pretrain


@pytest.fixture(scope="module")
def pipeline():
    from util import melinoe_test_config

    cfg = melinoe_test_config()  # 8 experts top-2, C=2
    lm = ClusterLM(SyntheticConfig(vocab=cfg.vocab, seq_len=48, n_clusters=4, seed=0))
    base = pretrain(cfg, lm.batches(6, seed=1), steps=16, log_every=100, verbose=False)
    ft = melinoe_finetune(cfg, base.params, lm.batches(6, seed=2), steps=14,
                          log_every=100, verbose=False)
    merged = merge_lora(cfg, ft.params, ft.lora, lora_scale(cfg.melinoe))
    return cfg, lm, base.params, merged, ft


def test_finetune_reduces_engine_transfers(pipeline):
    """Paper Table 3: fine-tuned model needs fewer CPU->GPU transfers."""
    cfg, lm, base, merged, ft = pipeline
    rng = np.random.default_rng(5)
    prompts = np.stack([lm.sample_sequence(rng, cluster=1)[0][:24] for _ in range(2)])
    C = cfg.melinoe_cache_capacity()
    r_base = OffloadedMoEEngine(cfg, base, capacity=C, policy="lfu").generate(
        prompts, max_new_tokens=12
    )
    r_ft = OffloadedMoEEngine(cfg, merged, capacity=C, policy="lfu").generate(
        prompts, max_new_tokens=12
    )
    assert r_ft["metrics"].transfers <= r_base["metrics"].transfers
    assert r_ft["throughput_tok_s"] >= r_base["throughput_tok_s"]


def test_quality_retained(pipeline):
    """Paper Table 2: fine-tuning must not degrade held-out NLL (much)."""
    cfg, lm, base, merged, ft = pipeline
    ev = eval_batches(lm, 2, 6)
    nll_b = eval_nll(cfg, base, ev)
    nll_f = eval_nll(cfg, merged, ev)
    assert nll_f < nll_b * 1.15, (nll_b, nll_f)


def test_cs_loss_went_down_during_ft(pipeline):
    cfg, lm, base, merged, ft = pipeline
    assert ft.history[-1]["cs_loss"] < ft.history[0]["cs_loss"]
