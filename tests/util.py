"""Shared test helpers."""
import dataclasses

from repro.configs import get_config
from repro.configs.base import MoESpec


def melinoe_test_config(arch: str = "granite-moe-1b-a400m", *, num_experts: int = 8,
                        top_k: int = 2):
    """Reduced config with enough experts that routing concentration has
    somewhere to go (the 4-expert smoke reduction is degenerate for
    MELINOE: C=2 with K=2 leaves nothing to learn)."""
    cfg = get_config(arch + "-smoke")
    bd = dict(cfg.block_defs)
    for name, b in bd.items():
        if b.moe is not None:
            bd[name] = dataclasses.replace(
                b,
                moe=MoESpec(num_experts=num_experts, top_k=top_k, d_ff=b.moe.d_ff,
                            num_shared=b.moe.num_shared,
                            shared_d_ff=b.moe.shared_d_ff,
                            capacity_factor=2.0),
            )
    mel = dataclasses.replace(cfg.melinoe, cache_capacity=num_experts // 4)
    return dataclasses.replace(cfg, block_defs=bd, melinoe=mel,
                               name=cfg.name + f"-e{num_experts}")
