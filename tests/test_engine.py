"""Offload engine: exactness vs the fused decode path, transfer
accounting, quantized residency, baseline policies (Sec 3.2 / Sec 4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.baselines import BASELINES, make_engine
from repro.core.offload_engine import HardwareProfile, OffloadedMoEEngine
from repro.models import Runtime, decode_step, init_params, prefill


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("granite-moe-1b-a400m-smoke")
    params = init_params(jax.random.key(0), cfg, jnp.float32)
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab)
    return cfg, params, toks


def reference_tokens(cfg, params, toks, n):
    rt = Runtime(zero_drop=True)
    lg, cache = prefill(params, cfg, toks, rt, n_slots=toks.shape[1] + n)
    out = [jnp.argmax(lg, -1).astype(jnp.int32)]
    for _ in range(n - 1):
        lg, cache, _ = decode_step(params, cfg, out[-1], cache, rt)
        out.append(jnp.argmax(lg, -1).astype(jnp.int32))
    return jnp.concatenate(out, 1)


def test_engine_exact_with_full_cache(setup):
    cfg, params, toks = setup
    E = cfg.moe_spec.num_experts
    eng = OffloadedMoEEngine(cfg, params, capacity=E)
    res = eng.generate(toks, max_new_tokens=5)
    ref = reference_tokens(cfg, params, toks, 5)
    assert bool(jnp.all(res["tokens"] == ref))


def test_engine_output_correct_even_under_tiny_cache(setup):
    """The cache changes WHEN weights move, never WHAT is computed."""
    cfg, params, toks = setup
    eng = OffloadedMoEEngine(cfg, params, capacity=1)
    res = eng.generate(toks, max_new_tokens=5)
    ref = reference_tokens(cfg, params, toks, 5)
    assert bool(jnp.all(res["tokens"] == ref))
    assert res["metrics"].transfers > 0


def test_transfers_decrease_with_capacity(setup):
    cfg, params, toks = setup
    E = cfg.moe_spec.num_experts
    tx = []
    for C in (1, 2, E):
        eng = OffloadedMoEEngine(cfg, params, capacity=C)
        res = eng.generate(toks, max_new_tokens=4)
        tx.append(res["metrics"].transfers)
    assert tx[0] >= tx[1] >= tx[2]


def test_eq3_throughput_decreases_with_transfers(setup):
    cfg, params, toks = setup
    E = cfg.moe_spec.num_experts
    r_small = OffloadedMoEEngine(cfg, params, capacity=1).generate(toks, 4)
    r_big = OffloadedMoEEngine(cfg, params, capacity=E).generate(toks, 4)
    assert r_big["throughput_tok_s"] > r_small["throughput_tok_s"]


def test_quantized_engine_runs_and_counts_smaller_transfers(setup):
    cfg, params, toks = setup
    e_fp = OffloadedMoEEngine(cfg, params, capacity=2)
    e_q = OffloadedMoEEngine(cfg, params, capacity=2, quantized=True)
    assert e_q.expert_bytes < e_fp.expert_bytes * 0.6
    res = e_q.generate(toks, max_new_tokens=3)
    assert not bool(jnp.any(res["tokens"] < 0))


@pytest.mark.parametrize("name", sorted(BASELINES))
def test_baseline_policies_run(setup, name):
    cfg, params, toks = setup
    eng = make_engine(cfg, params, BASELINES[name], capacity=2)
    res = eng.generate(toks, max_new_tokens=3)
    m = res["metrics"]
    assert m.decode_tokens == 3
    if name == "stream_all":
        # every activation transfers: K experts x L layers x tokens x batch
        K, L = cfg.moe_spec.top_k, cfg.n_moe_layers
        n_tok = toks.shape[0] * (toks.shape[1] + 2)  # prefill + 2 decode steps
        assert m.transfers == K * L * n_tok
    if name == "cpu_execute":
        assert m.transfers == 0 and m.host_executed > 0


def test_prefetch_counts_separately(setup):
    cfg, params, toks = setup
    E = cfg.moe_spec.num_experts
    eng = OffloadedMoEEngine(cfg, params, capacity=2)
    scores = np.zeros((cfg.n_moe_layers, E))
    scores[:, :2] = 1.0
    eng.prefetch(scores)
    assert eng.metrics.prefetch_transfers == cfg.n_moe_layers * 2
    assert eng.metrics.transfers == 0
