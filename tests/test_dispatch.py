"""Kernel-dispatch subsystem: backend selection unit tests, and parity
tests asserting every op family gives the same model outputs under the
"ref" and "pallas"-interpret backends (attention prefill, MoE forward,
mamba2 scan, quantized matmul)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import AttnSpec, MoESpec, SSMSpec
from repro.kernels import dispatch
from repro.models import Runtime
from repro.models import attention as attn_mod
from repro.models import mamba2 as mamba_mod
from repro.models import moe as moe_mod
from repro.models.model import apply_model, init_params

pytestmark = pytest.mark.kernels

RT_REF = Runtime(kernel_backend="ref")
RT_PALLAS = Runtime(kernel_backend="auto")  # CPU -> pallas interpret


@pytest.fixture(autouse=True)
def _no_env_backend(monkeypatch):
    """Selection/parity assertions must not depend on an externally
    exported REPRO_KERNEL_BACKEND."""
    monkeypatch.delenv(dispatch.ENV_VAR, raising=False)


# ---------------------------------------------------------------------------
# Selection unit tests
# ---------------------------------------------------------------------------


def test_resolve_ref():
    c = dispatch.resolve("moe_gmm", "ref")
    assert c.backend == "ref" and not c.use_pallas


def test_resolve_auto_on_cpu_is_pallas_interpret():
    c = dispatch.resolve("moe_gmm", "auto", platform="cpu")
    assert c.use_pallas and c.interpret
    c = dispatch.resolve("moe_gmm", "auto", platform="tpu")
    assert c.use_pallas and not c.interpret


def test_resolve_explicit_interpret_wins():
    c = dispatch.resolve("flash_attn", "pallas", interpret=True, platform="tpu")
    assert c.use_pallas and c.interpret


def test_per_op_overrides():
    spec = "auto,flash_attn=ref"
    assert dispatch.resolve("flash_attn", spec).backend == "ref"
    assert dispatch.resolve("moe_gmm", spec).use_pallas


def test_parse_spec_rejects_unknown():
    with pytest.raises(ValueError):
        dispatch.parse_spec("warp_drive=pallas")
    with pytest.raises(ValueError):
        dispatch.parse_spec("moe_gmm=cuda")
    with pytest.raises(ValueError):
        dispatch.resolve("not_an_op", "ref")


def test_env_override(monkeypatch):
    monkeypatch.setenv(dispatch.ENV_VAR, "ref")
    assert dispatch.resolve("moe_gmm", "pallas").backend == "ref"
    monkeypatch.setenv(dispatch.ENV_VAR, "moe_gmm=pallas")
    assert dispatch.resolve("moe_gmm", "ref").use_pallas
    assert dispatch.resolve("ssd_scan", "ref").backend == "ref"
    monkeypatch.delenv(dispatch.ENV_VAR)
    assert dispatch.resolve("moe_gmm", "ref").backend == "ref"


def test_env_override_merges_per_op(monkeypatch):
    """A per-op-only env override adjusts that op and leaves the
    caller's spec in force for every other family."""
    monkeypatch.setenv(dispatch.ENV_VAR, "flash_attn=ref")
    assert dispatch.resolve("flash_attn", "auto").backend == "ref"
    assert dispatch.resolve("moe_gmm", "auto").use_pallas
    assert dispatch.resolve("int4_matmul", "ref").backend == "ref"


def test_sharded_runtime_pins_ref_even_under_env(monkeypatch):
    """The shard_map path must keep the reference kernels no matter what
    REPRO_KERNEL_BACKEND says (single-device kernel bodies)."""
    monkeypatch.setenv(dispatch.ENV_VAR, "auto")
    monkeypatch.setattr(Runtime, "sharded", property(lambda self: True))
    rt = Runtime(kernel_backend="auto")
    assert rt.kernel_choice("moe_gmm").backend == "ref"
    monkeypatch.setattr(Runtime, "sharded", property(lambda self: False))
    assert rt.kernel_choice("moe_gmm").use_pallas  # env honoured unsharded


def test_compiler_params_shim_matches_installed_jax():
    kw = dispatch.compiler_params(dimension_semantics=("parallel", "arbitrary"))
    # whatever the pinned JAX exposes, the shim must produce kwargs that
    # pallas_call accepts (empty dict = no params supported)
    assert isinstance(kw, dict)
    assert set(kw) <= {"compiler_params"}


def test_runtime_legacy_use_kernels_maps_to_auto():
    rt = Runtime(use_kernels=True)
    assert rt.kernel_backend == "auto"
    assert rt.kernel_choice("moe_gmm").use_pallas
    rt = Runtime(use_kernels=False)
    assert rt.kernel_backend == "ref"
    assert not Runtime().kernel_choice("moe_gmm").use_pallas


def test_runtime_per_op_backend():
    rt = Runtime(kernel_backend="auto,ssd_scan=ref")
    assert rt.kernel_choice("moe_gmm").use_pallas
    assert not rt.kernel_choice("ssd_scan").use_pallas


# ---------------------------------------------------------------------------
# Op-level parity (ref backend vs pallas interpret)
# ---------------------------------------------------------------------------


def test_moe_forward_parity():
    spec = MoESpec(num_experts=4, top_k=2, d_ff=64, capacity_factor=2.0)
    params = moe_mod.init_moe(jax.random.key(0), 32, spec, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (24, 32))
    y_ref, _ = moe_mod.apply_moe(params, x, spec, RT_REF)
    y_pal, _ = moe_mod.apply_moe(params, x, spec, RT_PALLAS)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               atol=1e-5, rtol=1e-5)


def test_attention_prefill_parity():
    spec = AttnSpec(n_heads=4, n_kv_heads=2, head_dim=16)
    params = attn_mod.init_attn(jax.random.key(2), 32, spec, jnp.float32)
    x = jax.random.normal(jax.random.key(3), (2, 48, 32))
    pos = jnp.broadcast_to(jnp.arange(48), (2, 48))
    y_ref = attn_mod.attend_full(params, spec, x, pos, None, rt=RT_REF)
    y_pal = attn_mod.attend_full(params, spec, x, pos, None, rt=RT_PALLAS)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               atol=1e-5, rtol=1e-4)


@pytest.mark.parametrize("n_groups", [1, 2])
def test_mamba2_scan_parity(n_groups):
    spec = SSMSpec(d_state=8, d_conv=4, expand=2, head_dim=8,
                   n_groups=n_groups, chunk=16)
    params = mamba_mod.init_mamba(jax.random.key(4), 32, spec, jnp.float32)
    x = jax.random.normal(jax.random.key(5), (2, 32, 32)) * 0.3
    y_ref, st_ref = mamba_mod.apply_mamba_full(params, x, spec,
                                               return_state=True, rt=RT_REF)
    y_pal, st_pal = mamba_mod.apply_mamba_full(params, x, spec,
                                               return_state=True, rt=RT_PALLAS)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st_pal.ssm), np.asarray(st_ref.ssm),
                               atol=1e-5, rtol=1e-4)


def test_mamba2_initial_state_parity():
    """The kernel path must honour a carried SSM state (chained prefill) —
    previously an explicit gap that silently fell back to the reference."""
    spec = SSMSpec(d_state=8, d_conv=4, expand=2, head_dim=8, n_groups=2,
                   chunk=16)
    params = mamba_mod.init_mamba(jax.random.key(6), 32, spec, jnp.float32)
    x = jax.random.normal(jax.random.key(7), (2, 32, 32)) * 0.3
    _, st = mamba_mod.apply_mamba_full(params, x[:, :16], spec,
                                       return_state=True, rt=RT_REF)
    y_ref, _ = mamba_mod.apply_mamba_full(params, x[:, 16:], spec,
                                          init_state=st, return_state=True,
                                          rt=RT_REF)
    y_pal, _ = mamba_mod.apply_mamba_full(params, x[:, 16:], spec,
                                          init_state=st, return_state=True,
                                          rt=RT_PALLAS)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               atol=1e-5, rtol=1e-4)
    # and chained == full-sequence (the recurrence actually carried over)
    y_full, _ = mamba_mod.apply_mamba_full(params, x, spec,
                                           return_state=True, rt=RT_PALLAS)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_full[:, 16:]),
                               atol=1e-4, rtol=1e-3)


def test_qmatmul_parity():
    from repro.core.quant import matmul_layout, qmatmul, quantize_linear

    w = jax.random.normal(jax.random.key(8), (128, 96)) * 0.05
    ql = quantize_linear(w, group=32, iters=4)
    x = jax.random.normal(jax.random.key(9), (8, 128))
    y_ref = qmatmul(x, ql, backend="ref")
    y_pal = qmatmul(x, matmul_layout(ql), backend="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               atol=1e-5, rtol=1e-5)


def test_qmatmul_after_numpy_roundtrip():
    """The offload engine tree-maps whole QTensors through np.asarray for
    host storage, which turns the static shape/group ints into 0-d
    arrays — the fused path must coerce them back (regression)."""
    from repro.core.quant import QTensor, matmul_layout, qmatmul, quantize_linear

    w = jax.random.normal(jax.random.key(10), (64, 32)) * 0.05
    ql = quantize_linear(w, group=32, iters=2)
    ql_np = QTensor(*[np.asarray(f) for f in ql])  # host-store round trip
    x = jax.random.normal(jax.random.key(11), (4, 64))
    y_ref = qmatmul(x, ql, backend="ref")
    y_pal = qmatmul(x, matmul_layout(QTensor(*[jnp.asarray(f) for f in ql_np])),
                    backend="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# Model-level parity: full forward under backend "auto" on CPU routes the
# MoE + attention + mamba2 paths through Pallas interpret kernels and the
# logits must match the reference backend.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["granite-moe-1b-a400m-smoke", "mamba2-130m-smoke"])
def test_model_forward_parity(arch):
    cfg = get_config(arch)
    params = init_params(jax.random.key(0), cfg, jnp.float32)
    toks = jax.random.randint(jax.random.key(1), (2, 24), 0, cfg.vocab)
    logits_ref, _ = apply_model(params, cfg, toks, RT_REF)
    logits_pal, _ = apply_model(params, cfg, toks, RT_PALLAS)
    np.testing.assert_allclose(np.asarray(logits_pal), np.asarray(logits_ref),
                               atol=2e-4, rtol=1e-3)
