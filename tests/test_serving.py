"""Continuous-batching serving subsystem: output equivalence with the
static engine, EOS/budget retirement, slot-reuse invariants, scheduler
determinism, and the expert-affinity >= FCFS cache property."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.expert_cache import ModelExpertCache
from repro.inference.engine import Request, ServingEngine
from repro.models.model import init_params
from repro.serving import (
    BatchState,
    ContinuousBatchingServer,
    OffloadedWaveServer,
    RequestQueue,
    ServeRequest,
    TrafficConfig,
    get_scheduler,
    prefill_expert_scores,
    serve_static,
    synthesize_workload,
)
from repro.data.synthetic import ClusterLM, SyntheticConfig


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("granite-moe-1b-a400m-smoke")
    params = init_params(jax.random.key(0), cfg, jnp.float32)
    return cfg, params


def mk_requests(cfg, lens, budgets, *, seed=0, arrivals=None, temps=None):
    rng = np.random.default_rng(seed)
    return [
        ServeRequest(
            rid=i,
            prompt=rng.integers(0, cfg.vocab, lens[i]).astype(np.int32),
            max_new_tokens=budgets[i],
            arrival_time=0.0 if arrivals is None else arrivals[i],
            temperature=0.0 if temps is None else temps[i],
        )
        for i in range(len(lens))
    ]


# ---------------------------------------------------------------------------
# Continuous batching: correctness
# ---------------------------------------------------------------------------


def test_continuous_matches_single_request_engine(setup):
    """In-flight batching must not change any request's tokens: each
    completion equals the request decoded alone through the static
    engine (mixed prompt lengths AND mixed budgets)."""
    cfg, params = setup
    reqs = mk_requests(cfg, lens=[6, 11, 8, 14, 9], budgets=[7, 3, 9, 5, 6])
    srv = ContinuousBatchingServer(cfg, params, n_slots=2, max_len=32)
    results, mt = srv.run(RequestQueue(reqs))
    assert [r.rid for r in results] == [0, 1, 2, 3, 4]
    eng = ServingEngine(cfg, params, max_batch=1)
    for req, res in zip(reqs, results):
        ref = eng.generate_batch(
            [Request(prompt=req.prompt, max_new_tokens=req.max_new_tokens)]
        )[0]
        assert res.finish_reason == "length"
        np.testing.assert_array_equal(res.tokens, ref.tokens)
    assert mt.generated_tokens == sum(r.max_new_tokens for r in reqs)
    assert len(mt.latencies) == len(reqs)


def test_continuous_beats_static_on_mixed_budgets(setup):
    """Acceptance: on a mixed-length workload, continuous batching emits
    the same tokens per request in strictly fewer decode iterations than
    padded static batching."""
    cfg, params = setup
    # equal prompt lengths (so static left-padding is a no-op and the
    # outputs are comparable), strongly mixed decode budgets
    budgets = [3, 12, 5, 9, 4, 11, 6, 8]
    reqs = mk_requests(cfg, lens=[8] * len(budgets), budgets=budgets)
    srv = ContinuousBatchingServer(cfg, params, n_slots=4, max_len=24)
    cont, mt = srv.run(RequestQueue(reqs))
    stat, static_iters = serve_static(cfg, params, reqs, batch_size=4)
    for a, b in zip(cont, stat):
        assert a.rid == b.rid
        np.testing.assert_array_equal(a.tokens, b.tokens)
    assert mt.decode_steps < static_iters, (mt.decode_steps, static_iters)
    # the saving is the point: static pays the chunk-max budget per row
    assert mt.occupancy > 0.5


def test_stop_token_retires_early_and_slot_is_reused(setup):
    """EOS-style retirement: a stop token ends the request mid-budget,
    the completion carries finish_reason='stop', and the freed slot
    serves a queued request."""
    cfg, params = setup
    reqs = mk_requests(cfg, lens=[9, 9, 9], budgets=[10, 10, 10])
    # find the victim's greedy tokens, then declare its 3rd token an EOS
    eng = ServingEngine(cfg, params, max_batch=1)
    ref = eng.generate_batch([Request(prompt=reqs[0].prompt, max_new_tokens=10)])[0]
    reqs[0].stop_tokens = (int(ref.tokens[2]),)
    srv = ContinuousBatchingServer(cfg, params, n_slots=1, max_len=32)
    results, mt = srv.run(RequestQueue(reqs))
    assert results[0].finish_reason == "stop"
    assert len(results[0].tokens) == 3
    np.testing.assert_array_equal(results[0].tokens, ref.tokens[:3])
    # the other two requests ran to budget through the same single slot
    assert [r.finish_reason for r in results[1:]] == ["length", "length"]
    assert all(len(r.tokens) == 10 for r in results[1:])


def test_arrivals_respected_and_latencies_recorded(setup):
    cfg, params = setup
    reqs = mk_requests(cfg, lens=[8, 8, 8], budgets=[4, 4, 4],
                       arrivals=[0.0, 100.0, 100.0])
    srv = ContinuousBatchingServer(cfg, params, n_slots=2, max_len=16)
    results, mt = srv.run(RequestQueue(reqs))
    assert len(results) == 3
    # rid 1/2 cannot start before their arrival on the virtual clock
    assert results[1].start_time >= 100.0 and results[2].start_time >= 100.0
    assert all(r.latency >= 0 for r in results)


def test_per_request_temperature_sampling(setup):
    """Satellite fix: a greedy row must stay greedy even when another
    row in the same batch samples at high temperature."""
    cfg, params = setup
    rng = np.random.default_rng(0)
    p = rng.integers(0, cfg.vocab, 10).astype(np.int32)
    eng = ServingEngine(cfg, params, max_batch=2)
    greedy_ref = eng.generate_batch([Request(p, 8), Request(p, 8)])
    mixed = eng.generate_batch([Request(p, 8, 0.0), Request(p, 8, 2.0)], seed=3)
    np.testing.assert_array_equal(mixed[0].tokens, greedy_ref[0].tokens)
    assert not np.array_equal(mixed[1].tokens, greedy_ref[1].tokens)


def test_continuous_sampling_with_free_slots(setup):
    """Regression: mixed greedy/sampled rows alongside FREE slots must
    not crash key construction, greedy rows must match the greedy
    reference, and request-keyed sampling must be reproducible."""
    cfg, params = setup
    def mk():
        reqs = mk_requests(cfg, lens=[8, 8], budgets=[6, 6])
        reqs[1].temperature = 1.5
        return reqs
    # n_slots=3 > n_requests: one slot stays free throughout
    srv = ContinuousBatchingServer(cfg, params, n_slots=3, max_len=24, seed=5)
    res, _ = srv.run(RequestQueue(mk()))
    eng = ServingEngine(cfg, params, max_batch=1)
    ref = eng.generate_batch([Request(prompt=mk()[0].prompt, max_new_tokens=6)])[0]
    np.testing.assert_array_equal(res[0].tokens, ref.tokens)  # greedy untouched
    # same seed, fresh server -> identical sampled tokens
    srv2 = ContinuousBatchingServer(cfg, params, n_slots=3, max_len=24, seed=5)
    res2, _ = srv2.run(RequestQueue(mk()))
    np.testing.assert_array_equal(res[1].tokens, res2[1].tokens)


def test_generate_batch_honors_stop_tokens(setup):
    cfg, params = setup
    rng = np.random.default_rng(1)
    p = rng.integers(0, cfg.vocab, 9).astype(np.int32)
    eng = ServingEngine(cfg, params, max_batch=1)
    full = eng.generate_batch([Request(prompt=p, max_new_tokens=8)])[0]
    assert full.finish_reason == "length"
    stopped = eng.generate_batch(
        [Request(prompt=p, max_new_tokens=8, stop_tokens=(int(full.tokens[3]),))]
    )[0]
    assert stopped.finish_reason == "stop"
    assert len(stopped.tokens) <= 4
    np.testing.assert_array_equal(stopped.tokens, full.tokens[: len(stopped.tokens)])


# ---------------------------------------------------------------------------
# BatchState invariants
# ---------------------------------------------------------------------------


def test_batch_state_slot_invariants():
    bs = BatchState(2, max_len=16)
    r0 = ServeRequest(rid=0, prompt=np.zeros(4, np.int32), max_new_tokens=3)
    r1 = ServeRequest(rid=1, prompt=np.zeros(4, np.int32), max_new_tokens=2)
    bs.occupy(0, r0, now=1.0)
    assert bs.free_slots() == [1] and bs.active_slots() == [0]
    with pytest.raises(AssertionError):  # double occupancy
        bs.occupy(0, r1, now=1.0)
    with pytest.raises(AssertionError):  # same rid twice
        bs.occupy(1, ServeRequest(rid=0, prompt=np.zeros(2, np.int32)), now=1.0)
    with pytest.raises(AssertionError):  # KV budget exceeded
        bs.occupy(1, ServeRequest(rid=9, prompt=np.zeros(10, np.int32),
                                  max_new_tokens=10), now=1.0)
    # budget retirement
    assert bs.append_token(0, 5) is None
    assert bs.append_token(0, 6) is None
    assert bs.append_token(0, 7) == "length"
    res = bs.retire(0, now=2.0, reason="length")
    assert res.rid == 0 and list(res.tokens) == [5, 6, 7]
    assert bs.free_slots() == [0, 1]
    # stop-token retirement beats budget
    bs.occupy(1, ServeRequest(rid=2, prompt=np.zeros(2, np.int32),
                              max_new_tokens=5, stop_tokens=(42,)), now=3.0)
    assert bs.append_token(1, 42) == "stop"


# ---------------------------------------------------------------------------
# Schedulers + traffic
# ---------------------------------------------------------------------------


def _scored(rid, arrival, experts, *, L=2, E=8, budget=8):
    scores = np.zeros((L, E))
    scores[:, list(experts)] = 1.0
    return ServeRequest(rid=rid, prompt=np.zeros(4, np.int32), max_new_tokens=budget,
                        arrival_time=arrival, expert_scores=scores)


def test_scheduler_ordering_deterministic():
    a = _scored(0, 0.0, {0, 1}, budget=20)
    b = _scored(1, 1.0, {4, 5}, budget=2)
    c = _scored(2, 2.0, {0, 1}, budget=10)
    d = _scored(3, 3.0, {4, 5}, budget=5)
    ready = [d, c, b, a]
    assert [r.rid for r in get_scheduler("fcfs").order(ready)] == [0, 1, 2, 3]
    assert [r.rid for r in get_scheduler("sjf").order(ready)] == [1, 3, 2, 0]
    # affinity: seed with oldest (a), then chain by overlap a->c, then b->d
    aff = get_scheduler("expert-affinity", top_c=2)
    assert [r.rid for r in aff.order(ready)] == [0, 2, 1, 3]
    # hot context steers the seed pick
    assert [r.rid for r in aff.order(ready, hot=[b])][0] == 1
    # requests without scores degrade to FCFS
    plain = [ServeRequest(rid=i, prompt=np.zeros(2, np.int32), arrival_time=float(-i))
             for i in range(3)]
    assert [r.rid for r in get_scheduler("expert-affinity").order(plain)] == [2, 1, 0]


def test_traffic_generator_shapes_and_arrivals():
    lm = ClusterLM(SyntheticConfig(vocab=512, n_clusters=4, seq_len=64, seed=0))
    for arrival in ("poisson", "bursty", "all_at_once"):
        tcfg = TrafficConfig(n_requests=12, arrival=arrival, rate=2.0, burst_size=3,
                             prompt_len=(4, 9), max_new_tokens=(2, 5),
                             n_clusters=2, seed=1)
        reqs = synthesize_workload(lm, tcfg)
        assert len(reqs) == 12
        times = [r.arrival_time for r in reqs]
        assert times == sorted(times)
        assert all(4 <= r.prompt_len <= 9 for r in reqs)
        assert all(2 <= r.max_new_tokens <= 5 for r in reqs)
        assert all(r.cluster in (0, 1) for r in reqs)
        if arrival == "bursty":
            assert len(set(times)) == 4  # 12 requests in bursts of 3
        if arrival == "all_at_once":
            assert set(times) == {0.0}
    # same seed -> same trace
    r1 = synthesize_workload(lm, TrafficConfig(seed=7))
    r2 = synthesize_workload(lm, TrafficConfig(seed=7))
    assert all(np.array_equal(a.prompt, b.prompt) and a.arrival_time == b.arrival_time
               for a, b in zip(r1, r2))


def test_request_queue_semantics():
    reqs = [ServeRequest(rid=i, prompt=np.zeros(2, np.int32), arrival_time=float(i))
            for i in range(3)]
    q = RequestQueue(reqs)
    assert len(q) == 3 and q.next_arrival() == 0.0
    assert [r.rid for r in q.ready(1.5)] == [0, 1]
    assert q.backlog(1.5) == 2
    q.admit(reqs[0])
    assert [r.rid for r in q.ready(1.5)] == [1]
    assert len(q) == 2


def test_serve_request_identity_semantics():
    """ServeRequest/ServeResult carry ndarrays, so the dataclasses must
    use identity eq/hash: a generated __eq__ would crash list.remove
    and `in` with 'truth value of an array is ambiguous' the moment two
    requests share field values (regression for the eq=False hazard)."""
    from repro.serving import ServeResult

    a = ServeRequest(rid=0, prompt=np.zeros(3, np.int32))
    b = ServeRequest(rid=0, prompt=np.zeros(3, np.int32))  # same fields
    assert a != b and a == a
    assert len({a, b}) == 2  # hashable, by identity
    pool = [a, b]
    pool.remove(b)  # would raise on a field-wise __eq__
    assert pool == [a]
    ra = ServeResult(rid=0, tokens=np.zeros(2, np.int32), finish_reason="stop")
    rb = ServeResult(rid=0, tokens=np.zeros(2, np.int32), finish_reason="stop")
    assert ra != rb and len({ra, rb}) == 2


def test_request_queue_out_of_order_push():
    """push keeps the pool arrival-ordered even when arrivals land out
    of order (a late-arriving trace entry must not corrupt ready())."""
    q = RequestQueue()
    times = [3.0, 1.0, 2.0, 0.5, 2.0]
    reqs = [ServeRequest(rid=i, prompt=np.zeros(2, np.int32),
                         arrival_time=t) for i, t in enumerate(times)]
    for r in reqs:
        assert q.push(r)
    assert [r.rid for r in q.ready(10.0)] == [3, 1, 2, 4, 0]
    assert q.next_arrival() == 0.5
    # equal arrival times tie-break by rid, stably
    assert [r.rid for r in q.ready(2.0)] == [3, 1, 2, 4]


def test_request_queue_bound_sheds_latest():
    reqs = [ServeRequest(rid=i, prompt=np.zeros(2, np.int32),
                         arrival_time=float(i)) for i in range(5)]
    q = RequestQueue(reqs, max_pending=2)
    # future arrivals are not backlog: nothing shed at construction
    assert len(q) == 5 and q.shed_count == 0
    assert q.enforce_bound(0.5) == []  # backlog of 1 <= bound
    # three arrived, bound 2 -> the latest arrival is shed
    over = q.enforce_bound(2.5)
    assert [r.rid for r in over] == [2]
    assert q.shed_count == 1 and len(q) == 4
    # a live push over the total bound sheds the latest immediately
    late = ServeRequest(rid=9, prompt=np.zeros(2, np.int32),
                        arrival_time=10.0)
    assert not q.push(late)
    # an early arrival still displaces the latest pending one
    early = ServeRequest(rid=8, prompt=np.zeros(2, np.int32),
                         arrival_time=-1.0)
    assert not q.push(early)
    assert early in q.ready(0.0)
    drained = q.drain_shed()
    assert len(drained) == 3 and q.shed == [] and q.shed_count == 3


def test_request_queue_drop_expired():
    reqs = [ServeRequest(rid=i, prompt=np.zeros(2, np.int32),
                         arrival_time=0.0, slo=slo)
            for i, slo in enumerate([0.5, 2.0, None])]
    q = RequestQueue(reqs)
    expired = q.drop_expired(1.0)
    assert [r.rid for r in expired] == [0]  # slo=2.0 and best-effort stay
    assert len(q) == 2 and q.shed_count == 1
    assert q.drop_expired(1.0) == []


def test_profiling_shim_warns_and_reexports():
    """The deprecated serving.profiling alias must warn on import and
    still forward the scorers API until it is deleted."""
    import importlib
    import warnings

    with warnings.catch_warnings():  # first import may fire it too
        warnings.simplefilter("ignore", DeprecationWarning)
        import repro.serving.profiling as shim
    with pytest.warns(DeprecationWarning, match="scorers"):
        importlib.reload(shim)
    assert shim.prefill_expert_scores is prefill_expert_scores


# ---------------------------------------------------------------------------
# Expert affinity vs FCFS on a clustered workload
# ---------------------------------------------------------------------------


def test_expert_affinity_beats_fcfs_hit_rate_on_clustered_workload():
    """Deterministic scheduler+cache interaction: two clusters with
    disjoint expert preferences arrive interleaved; serving in affinity
    order keeps the per-layer cache hot, FCFS churns it."""
    L, E, C, K, T = 2, 16, 4, 2, 8
    rng = np.random.default_rng(0)
    pools = {0: np.arange(0, 4), 1: np.arange(8, 12)}  # disjoint Top-C sets
    reqs, traces = [], {}
    for i in range(8):
        k = i % 2  # interleaved arrival: worst case for FCFS
        reqs.append(_scored(i, float(i), set(pools[k]), L=L, E=E))
        reqs[-1].cluster = k
        traces[i] = rng.choice(pools[k], (T, L, K))  # routing inside the pool

    def replay(order):
        cache = ModelExpertCache(L, E, capacity=C, policy="lru")
        for r in order:
            for t in range(T):
                for l in range(L):
                    cache.access(l, traces[r.rid][t, l])
        return cache.stats()

    hit_fcfs = replay(get_scheduler("fcfs").order(reqs)).hit_rate
    aff = get_scheduler("expert-affinity", top_c=C)
    hit_aff = replay(aff.order(reqs)).hit_rate
    assert hit_aff >= hit_fcfs
    assert hit_aff > hit_fcfs + 0.05  # decisive, not a tie


def test_offloaded_wave_server_tokens_identical_across_policies(setup):
    """Scheduling changes WHEN experts move, never WHAT is computed: the
    wave server must emit identical tokens under every policy, while
    populating the per-policy cache telemetry."""
    cfg, params = setup
    lm = ClusterLM(SyntheticConfig(vocab=cfg.vocab, seq_len=24, n_clusters=4, seed=3))
    tcfg = TrafficConfig(n_requests=6, arrival="all_at_once", prompt_len=(8, 8),
                         max_new_tokens=(4, 4), n_clusters=3, seed=1)
    E = cfg.moe_spec.num_experts
    outs = {}
    for pol in ("fcfs", "expert-affinity"):
        reqs = synthesize_workload(lm, tcfg)
        prefill_expert_scores(cfg, params, reqs)
        sched = get_scheduler(pol) if pol == "fcfs" else get_scheduler(pol, top_c=2)
        srv = OffloadedWaveServer(cfg, params, capacity=max(E // 2, 1),
                                  scheduler=sched, wave_size=2)
        outs[pol] = srv.run(RequestQueue(reqs))
    res_f, mt_f = outs["fcfs"]
    res_a, mt_a = outs["expert-affinity"]
    for a, b in zip(res_a, res_f):
        assert a.rid == b.rid
        np.testing.assert_array_equal(a.tokens, b.tokens)
    for mt in (mt_f, mt_a):
        assert mt.cache_hits + mt.cache_misses > 0
        assert mt.modeled_time > 0
        assert mt.throughput_tok_s() > 0
        assert len(mt.latencies) == 6
