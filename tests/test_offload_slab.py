"""Slab-resident offload engine (PR 3): bit-exactness vs the fused
decode path and vs the pre-rewrite dict engine, vectorized cache
accounting equivalence, and the overlapped Eq.-3 clock invariant."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.expert_cache import LayerExpertCache
from repro.core.offload_engine import HardwareProfile, OffloadedMoEEngine
from repro.models import Runtime, decode_step, init_params, prefill


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("granite-moe-1b-a400m-smoke")
    params = init_params(jax.random.key(0), cfg, jnp.float32)
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab)
    return cfg, params, toks


def reference_tokens(cfg, params, toks, n):
    rt = Runtime(zero_drop=True)
    lg, cache = prefill(params, cfg, toks, rt, n_slots=toks.shape[1] + n)
    out = [jnp.argmax(lg, -1).astype(jnp.int32)]
    for _ in range(n - 1):
        lg, cache, _ = decode_step(params, cfg, out[-1], cache, rt)
        out.append(jnp.argmax(lg, -1).astype(jnp.int32))
    return jnp.concatenate(out, 1)


# ---------------------------------------------------------------------------
# Slab engine exactness
# ---------------------------------------------------------------------------


def test_slab_matches_decode_step_at_full_capacity(setup):
    cfg, params, toks = setup
    E = cfg.moe_spec.num_experts
    eng = OffloadedMoEEngine(cfg, params, capacity=E, impl="slab")
    res = eng.generate(toks, max_new_tokens=5)
    ref = reference_tokens(cfg, params, toks, 5)
    assert bool(jnp.all(res["tokens"] == ref))


def test_slab_exact_under_tiny_cache(setup):
    """The slab changes WHERE weights live, never WHAT is computed."""
    cfg, params, toks = setup
    eng = OffloadedMoEEngine(cfg, params, capacity=1, impl="slab")
    res = eng.generate(toks, max_new_tokens=5)
    ref = reference_tokens(cfg, params, toks, 5)
    assert bool(jnp.all(res["tokens"] == ref))
    assert res["metrics"].transfers > 0


@pytest.mark.parametrize("policy", ["lru", "lfu", "gamma"])
@pytest.mark.parametrize("capacity", [1, 2, 4])
def test_slab_matches_dict_engine_bit_for_bit(setup, policy, capacity):
    """At equal capacity/policy the slab engine reproduces the
    pre-rewrite dict engine: identical tokens AND identical transfer
    accounting (the cache manager is shared, the compute is grouped)."""
    cfg, params, toks = setup
    outs = {}
    for impl in ("dict", "slab"):
        eng = OffloadedMoEEngine(cfg, params, capacity=capacity,
                                 policy=policy, impl=impl)
        outs[impl] = (eng.generate(toks, max_new_tokens=4), eng)
    rd, ed = outs["dict"]
    rs, es = outs["slab"]
    assert bool(jnp.all(rd["tokens"] == rs["tokens"]))
    assert rd["metrics"].transfers == rs["metrics"].transfers
    assert rd["metrics"].transfer_bytes == rs["metrics"].transfer_bytes
    sd, ss = ed.cache.stats(), es.cache.stats()
    assert (sd.misses, sd.hits, sd.evictions) == (ss.misses, ss.hits, ss.evictions)


@pytest.mark.parametrize("backend", ["ref", "auto"])
def test_slab_matches_dict_engine_quantized(setup, backend):
    """INT4 residents: under "ref" both engines dequantize at fetch;
    under "auto" (Pallas interpret on CPU) the slab keeps matmul_layout
    buffers and dequantizes in-jit while the dict engine runs the fused
    kernel — same values either way, so tokens and transfers agree."""
    cfg, params, toks = setup
    rd = OffloadedMoEEngine(cfg, params, capacity=2, quantized=True,
                            impl="dict", kernel_backend=backend,
                            ).generate(toks, max_new_tokens=4)
    rs = OffloadedMoEEngine(cfg, params, capacity=2, quantized=True,
                            impl="slab", kernel_backend=backend,
                            ).generate(toks, max_new_tokens=4)
    assert bool(jnp.all(rd["tokens"] == rs["tokens"]))
    assert rd["metrics"].transfers == rs["metrics"].transfers


def test_slab_with_lora_matches_dict(setup):
    cfg, params, toks = setup
    from repro.core.lora import init_lora

    lora = init_lora(jax.random.key(5), cfg, cfg.melinoe)
    # b starts at zero; offset both factors so the low-rank term is live
    lora = jax.tree.map(lambda a: a + 0.01 * jnp.ones_like(a), lora)
    rd = OffloadedMoEEngine(cfg, params, capacity=2, lora=lora,
                            lora_scale=0.5, impl="dict").generate(toks, 4)
    rs = OffloadedMoEEngine(cfg, params, capacity=2, lora=lora,
                            lora_scale=0.5, impl="slab").generate(toks, 4)
    assert bool(jnp.all(rd["tokens"] == rs["tokens"]))


# ---------------------------------------------------------------------------
# Vectorized cache accounting
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ["lru", "lfu", "gamma"])
def test_access_batch_equals_sequential_access(policy):
    """access_batch must be EXACTLY the token-sequential loop: same
    missed list, hits/misses/evictions, resident set, counts (bitwise)
    and recency — on random traces across capacities."""
    rng = np.random.default_rng(0)
    E, K, N = 16, 4, 53
    for C in (1, 2, 3, 5, 8, 16):
        for trial in range(10):
            req = rng.choice(E, (N, K))
            a = LayerExpertCache(E, C, policy, gamma=0.9)
            b = LayerExpertCache(E, C, policy, gamma=0.9)
            m_seq = []
            for t in range(N):
                m_seq.extend(a.access(req[t]))
            m_bat = b.access_batch(req)
            assert m_seq == m_bat, (policy, C, trial)
            assert (a.hits, a.misses, a.evictions, a.step) == (
                b.hits, b.misses, b.evictions, b.step)
            assert a.resident == b.resident
            assert np.array_equal(a.counts, b.counts)
            assert np.array_equal(a.last_used, b.last_used)


def test_access_batch_single_row_and_1d():
    c1 = LayerExpertCache(8, 2, "lfu")
    c2 = LayerExpertCache(8, 2, "lfu")
    assert c1.access_batch(np.array([1, 5])) == c2.access([1, 5])
    assert c1.access_batch(np.array([[1, 5]])) == c2.access([1, 5])
    assert c1.resident == c2.resident


def test_prefill_credits_only_wanted_experts():
    """Satellite fix: prefill must credit the *wanted* set, not every
    resident — stale residents' LFU counts stay untouched so eviction
    order is not distorted by repeated prefills."""
    cache = LayerExpertCache(16, 4, "lfu")
    for _ in range(5):
        cache.access([0, 1])  # counts[0] == counts[1] == 5
    cache.prefill([2, 3])
    assert cache.counts[0] == 5.0 and cache.counts[1] == 5.0
    assert cache.counts[2] == 1.0 and cache.counts[3] == 1.0
    # repeated prefetch of the same set must not inflate anything
    c2, c3 = cache.counts[2], cache.counts[3]
    cache.prefill([2, 3])
    assert cache.counts[2] == c2 and cache.counts[3] == c3


# ---------------------------------------------------------------------------
# Overlapped Eq.-3 clock
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("capacity", [1, 2, 4])
def test_overlapped_clock_never_exceeds_serial(setup, capacity):
    cfg, params, toks = setup
    hw = HardwareProfile()
    for impl in ("slab", "dict"):
        eng = OffloadedMoEEngine(cfg, params, capacity=capacity, impl=impl)
        eng.generate(toks, max_new_tokens=5)
        m = eng.metrics
        t_o = m.modeled_time_overlapped(hw)
        t_s = m.modeled_time(hw)
        assert t_o <= t_s + 1e-12, (impl, capacity, t_o, t_s)
        assert t_o > 0
        # records reconcile with the scalar counters (same totals)
        assert sum(int(t.sum()) for t in m.step_tx) == m.transfers
        assert sum(int(t.sum()) for t in m.step_tx_bytes) == m.transfer_bytes
        assert sum(m.step_flops) == m.compute_flops


def test_overlap_hides_transfers_under_compute(setup):
    """When per-layer transfer time is below the per-layer compute time,
    the overlapped clock must approach pure compute + the first layer's
    (unhidden) fetches."""
    cfg, params, toks = setup
    # enormous link bandwidth -> transfers are nearly free to overlap
    hw = HardwareProfile(host_link_bw=1e15, transfer_latency=1e-12)
    eng = OffloadedMoEEngine(cfg, params, capacity=1, impl="slab")
    eng.generate(toks, max_new_tokens=4)
    m = eng.metrics
    t_compute = m.compute_flops / (hw.peak_flops * hw.mfu)
    t_o = m.modeled_time_overlapped(hw)
    assert t_compute <= t_o <= t_compute * 1.05
    # while the serial clock still charges every byte at real bandwidth
    assert m.modeled_time(HardwareProfile()) > t_o


def test_wave_server_reports_both_clocks(setup):
    cfg, params, _ = setup
    from repro.serving import (OffloadedWaveServer, RequestQueue,
                               TrafficConfig, synthesize_workload)
    from repro.data.synthetic import ClusterLM, SyntheticConfig

    lm = ClusterLM(SyntheticConfig(vocab=cfg.vocab, seq_len=16, seed=0))
    tcfg = TrafficConfig(n_requests=4, arrival="all_at_once",
                         prompt_len=(4, 8), max_new_tokens=(3, 5), seed=0)
    reqs = synthesize_workload(lm, tcfg)
    results, mt = OffloadedWaveServer(
        cfg, params, capacity=2, overlap=True).run(RequestQueue(reqs))
    assert len(results) == 4
    assert 0 < mt.modeled_time_overlapped <= mt.modeled_time_serial + 1e-12
    s = mt.summary()
    assert s["service_throughput_overlapped_tok_s"] >= s["service_throughput_serial_tok_s"]
