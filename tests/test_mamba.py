"""Mamba2 layer: chunked SSD vs sequential recurrence; decode chaining;
state handoff prefill -> decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SSMSpec
from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.models.mamba2 import (
    MambaState,
    apply_mamba_decode,
    apply_mamba_full,
    conv_dim,
    init_mamba,
    ssd_chunked,
)


@pytest.mark.parametrize("T,chunk", [(96, 32), (64, 64), (50, 16)])
def test_chunked_equals_sequential(T, chunk):
    B, H, P, N = 2, 4, 16, 8
    spec = SSMSpec(d_state=N, head_dim=P, chunk=chunk)
    x = jax.random.normal(jax.random.key(0), (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.key(1), (B, T, H)))
    A = -jnp.exp(jax.random.normal(jax.random.key(2), (H,)) * 0.3)
    Bm = jax.random.normal(jax.random.key(3), (B, T, N)) * 0.5
    Cm = jax.random.normal(jax.random.key(4), (B, T, N)) * 0.5
    y1, f1 = ssd_chunked(x, dt, A, Bm[:, :, None], Cm[:, :, None], spec)
    y2, f2 = ssd_scan_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), atol=1e-4, rtol=1e-3)


def test_full_layer_prefill_then_decode_matches_full_forward():
    d_model = 64
    spec = SSMSpec(d_state=16, head_dim=32, chunk=16)
    params = init_mamba(jax.random.key(0), d_model, spec, jnp.float32)
    B, T, G = 2, 24, 5
    x = jax.random.normal(jax.random.key(1), (B, T + G, d_model)) * 0.5
    y_full = apply_mamba_full(params, x, spec)
    y_pre, state = apply_mamba_full(params, x[:, :T], spec, return_state=True)
    np.testing.assert_allclose(
        np.asarray(y_pre), np.asarray(y_full[:, :T]), atol=2e-4, rtol=1e-3
    )
    outs = []
    for t in range(T, T + G):
        o, state = apply_mamba_decode(params, x[:, t : t + 1], state, spec)
        outs.append(o)
    dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(y_full[:, T:]), atol=2e-4, rtol=1e-3
    )


def test_conv_state_shape_and_zero_history():
    d_model = 32
    spec = SSMSpec(d_state=8, head_dim=16)
    params = init_mamba(jax.random.key(0), d_model, spec, jnp.float32)
    B = 2
    state = MambaState(
        conv=jnp.zeros((B, spec.d_conv - 1, conv_dim(spec, d_model))),
        ssm=jnp.zeros((B, spec.n_heads(d_model), spec.head_dim, spec.d_state)),
    )
    x = jax.random.normal(jax.random.key(1), (B, 1, d_model))
    y, state2 = apply_mamba_decode(params, x, state, spec)
    # first decode from empty state == full forward on a length-1 sequence
    y_ref = apply_mamba_full(params, x, spec)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5, rtol=1e-4)
    assert state2.conv.shape == state.conv.shape
