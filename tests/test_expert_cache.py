"""Invariants of the host-side expert cache (Def C.1) and trace simulator."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: deterministic local fallback
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.expert_cache import LayerExpertCache, ModelExpertCache, simulate_trace


@given(
    st.integers(0, 500),
    st.integers(2, 8),
    st.sampled_from(["lru", "lfu", "gamma"]),
)
@settings(max_examples=30, deadline=None)
def test_capacity_never_exceeded(seed, C, policy):
    E, K, T = 16, 4, 60
    rng = np.random.default_rng(seed)
    cache = LayerExpertCache(E, C, policy)
    for _ in range(T):
        req = rng.choice(E, K, replace=False)
        cache.access(req)
        assert len(cache.resident) <= C
        # every requested expert is resident right after the access
        assert set(int(e) for e in req) <= cache.resident or C < K
    assert cache.hits + cache.misses == T * K


def test_prefill_on_warm_cache_respects_capacity():
    """Prefilling a non-empty cache must evict (counting evictions) rather
    than push residency above C."""
    cache = LayerExpertCache(16, 4, "lfu")
    for e in range(4):  # warm the cache to full capacity
        cache.access([e])
    assert cache.resident == {0, 1, 2, 3}
    loaded = cache.prefill([10, 11, 12, 13])
    assert loaded == 4
    assert cache.resident == {10, 11, 12, 13}
    assert len(cache.resident) == 4  # never exceeded C
    assert cache.evictions == 4
    # overlapping prefetch: only the missing experts load, capacity holds
    loaded = cache.prefill([10, 11, 5])
    assert loaded == 1
    assert len(cache.resident) <= 4
    assert {10, 11, 5} <= cache.resident


def test_repeated_requests_hit_after_warmup():
    cache = LayerExpertCache(8, 4, "lfu")
    for _ in range(10):
        cache.access([0, 1])
    assert cache.misses == 2 and cache.hits == 18


def test_lru_evicts_oldest():
    cache = LayerExpertCache(8, 2, "lru")
    cache.access([0])
    cache.access([1])
    cache.access([2])  # evicts 0
    assert cache.resident == {1, 2}
    cache.access([1])  # refresh 1
    cache.access([3])  # evicts 2
    assert cache.resident == {1, 3}


def test_lfu_keeps_frequent():
    cache = LayerExpertCache(8, 2, "lfu")
    for _ in range(5):
        cache.access([0])
    cache.access([1])
    cache.access([2])  # evicts 1 (count 1) not 0 (count 5)
    assert 0 in cache.resident and 2 in cache.resident


def test_gamma_small_behaves_like_lru_on_cyclic_trace():
    """App D.8: small gamma is reactive (recency), large gamma frequency."""
    E, C = 6, 2
    # trace: expert 0 is frequent historically, then the hot set moves
    trace = [0] * 10 + [1, 2, 1, 2, 1, 2]
    miss = {}
    for gamma in (0.05, 1.0):
        cache = LayerExpertCache(E, C, "gamma", gamma=gamma)
        for e in trace:
            cache.access([e])
        miss[gamma] = cache.misses
    assert miss[0.05] <= miss[1.0]


def test_prefetch_reduces_misses():
    E, C, K, L, T = 16, 4, 4, 3, 40
    rng = np.random.default_rng(1)
    # routing concentrated on experts 0..5
    routing = rng.choice(6, (T, L, K))
    cold = simulate_trace(routing, capacity=C, policy="lfu")
    scores = np.zeros((L, E))
    scores[:, :6] = 1.0  # oracle prefetch
    warm = simulate_trace(routing, capacity=C, policy="lfu", prefetch=scores)
    assert warm.transfers <= cold.transfers


def test_transfers_monotone_in_capacity():
    rng = np.random.default_rng(2)
    routing = rng.choice(16, (50, 4, 4))
    prev = None
    for C in (2, 4, 8, 16):
        st_ = simulate_trace(routing, capacity=C, policy="lfu")
        if prev is not None:
            assert st_.transfers <= prev
        prev = st_.transfers
    assert prev == 16 * 4  # full cache: each (layer, expert) transfers once
