"""Activation predictor Psi (Sec 3.1.2)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.predictor import (
    PromptEmbedder,
    build_targets,
    init_predictor,
    predict_topc,
    predictor_kl_loss,
    train_predictor,
)


def test_embedder_deterministic_and_shaped():
    emb = PromptEmbedder(vocab=256)
    t = jnp.arange(10)
    e1, e2 = emb(t), emb(t)
    assert e1.shape == (768,)
    np.testing.assert_array_equal(np.asarray(e1), np.asarray(e2))
    batched = emb(jnp.stack([t, t + 1]))
    assert batched.shape == (2, 768)


def test_training_reduces_kl_and_recovers_cluster_preferences():
    L, E, n_clusters = 3, 16, 4
    rng = np.random.default_rng(0)
    # cluster c prefers experts [4c, 4c+4)
    cluster_pref = np.full((n_clusters, L, E), 0.1)
    for c in range(n_clusters):
        cluster_pref[c, :, 4 * c : 4 * c + 4] = 2.0
    cluster_emb = rng.standard_normal((n_clusters, 768)).astype(np.float32)
    N = 64
    ks = rng.integers(0, n_clusters, N)
    embs = jnp.asarray(cluster_emb[ks] + 0.1 * rng.standard_normal((N, 768)))
    t = cluster_pref[ks] + 0.05 * rng.standard_normal((N, L, E))
    targets = jnp.asarray(t / t.sum(-1, keepdims=True))

    pp = init_predictor(jax.random.key(0), L, E)
    l0 = float(predictor_kl_loss(pp, embs, targets))
    pp, hist = train_predictor(pp, embs, targets, epochs=30, lr=5e-3)
    assert hist[-1] < l0 * 0.5
    # Top-C prediction finds the right expert block for each cluster
    for c in range(n_clusters):
        top = predict_topc(pp, jnp.asarray(cluster_emb[c]), capacity=4)
        want = set(range(4 * c, 4 * c + 4))
        hitrate = np.mean([len(set(row) & want) / 4 for row in top])
        assert hitrate > 0.7, (c, top)


def test_build_targets_shapes():
    probs_list = [jnp.ones((2, 3, 5, 8)) / 8, jnp.ones((1, 3, 5, 8)) / 8]
    Y = build_targets(probs_list)
    assert Y.shape == (3, 3, 8)  # (B, L_total=2+1, E)
