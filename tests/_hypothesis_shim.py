"""Minimal deterministic stand-in for the slice of the hypothesis API the
test suite uses (``given`` / ``settings`` / ``strategies.integers,floats,
sampled_from``), so property tests still run in the offline container.

Unlike real hypothesis there is no shrinking or failure database: each
``@given`` test simply runs ``max_examples`` seeded random draws.
"""
from __future__ import annotations

import functools
import inspect

import numpy as np

_DEFAULT_EXAMPLES = 20
_SEED = 0xC0FFEE


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


class strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def sampled_from(values) -> _Strategy:
        values = list(values)
        return _Strategy(lambda rng: values[int(rng.integers(len(values)))])


def settings(max_examples: int = _DEFAULT_EXAMPLES, **_ignored):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn

    return deco


def given(*strats: _Strategy):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kw):
            n = getattr(wrapper, "_shim_max_examples",
                        getattr(fn, "_shim_max_examples", _DEFAULT_EXAMPLES))
            rng = np.random.default_rng(_SEED)
            for _ in range(n):
                fn(*args, *[s.draw(rng) for s in strats], **kw)

        # hide the drawn parameters from pytest's fixture resolution
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature([])
        return wrapper

    return deco
