"""HQQ-lite INT4 quantization properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: deterministic local fallback
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.quant import dequantize, quant_bytes, quant_error, quantize, unpack_codes


@given(st.integers(0, 100), st.sampled_from([16, 32, 64]), st.floats(0.01, 3.0))
@settings(max_examples=20, deadline=None)
def test_roundtrip_error_bounded_by_bin(seed, group, scale):
    w = jax.random.normal(jax.random.key(seed), (4, 128)) * scale
    qt = quantize(w, group=group, iters=0)
    err = jnp.abs(w - dequantize(qt, jnp.float32))
    # per-group max error is at most one quantization bin (scale)
    errg = err.reshape(4, 128 // group, group).max(-1)
    assert bool(jnp.all(errg <= qt.scale[..., 0] * 0.5 + 1e-6))


def test_hqq_refinement_not_worse_than_minmax():
    w = jax.random.normal(jax.random.key(1), (16, 256)) * 0.3
    # heavy-tailed weights are where HQQ helps
    w = w + (jax.random.uniform(jax.random.key(2), w.shape) < 0.02) * 2.0
    e0 = quant_error(w, quantize(w, group=64, iters=0))
    e1 = quant_error(w, quantize(w, group=64, iters=10))
    assert e1 <= e0 * 1.02


def test_codes_in_range_and_packing_invertible():
    w = jax.random.normal(jax.random.key(3), (8, 64))
    qt = quantize(w, group=32)
    q = np.asarray(unpack_codes(qt))
    assert q.min() >= 0 and q.max() <= 15
    assert qt.packed.shape == (8, 32)


def test_memory_savings():
    w = jax.random.normal(jax.random.key(4), (64, 512))
    qt = quantize(w, group=64)
    fp16_bytes = w.size * 2
    assert quant_bytes(qt) < fp16_bytes * 0.45  # ~3.5x smaller than fp16
