"""Per-architecture smoke tests (deliverable f): instantiate the REDUCED
variant of each assigned family, run one forward and one train step on
CPU, assert output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.launch.steps import build_train_step
from repro.models import Runtime, apply_model, decode_step, init_params, prefill
from repro.training.optim import OptConfig, init_opt_state

ALL = list(ASSIGNED) + ["olmoe", "mixtral-8x7b", "phi35-moe"]


def make_batch(cfg, B=2, T=24, seed=1):
    toks = jax.random.randint(jax.random.key(seed), (B, T), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.prefix_len:
        batch["prefix_embed"] = jax.random.normal(
            jax.random.key(seed + 1), (B, cfg.prefix_len, cfg.d_model)
        )
    return batch


@pytest.mark.parametrize("arch", ALL)
def test_smoke_forward(arch):
    cfg = get_config(arch + "-smoke")
    assert cfg.n_layers <= 3 and cfg.d_model <= 512
    if cfg.moe_spec:
        assert cfg.moe_spec.num_experts <= 4
    params = init_params(jax.random.key(0), cfg, jnp.float32)
    batch = make_batch(cfg)
    logits, aux = apply_model(
        params, cfg, batch["tokens"], Runtime(),
        prefix_embed=batch.get("prefix_embed"),
    )
    B, T = batch["tokens"].shape
    assert logits.shape == (B, T + cfg.prefix_len, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.parametrize("arch", ALL)
def test_smoke_train_step(arch):
    cfg = get_config(arch + "-smoke")
    rt = Runtime()
    params = init_params(jax.random.key(0), cfg, jnp.float32)
    opt = init_opt_state(params)
    step = jax.jit(build_train_step(cfg, rt, OptConfig(peak_lr=1e-3, total_steps=10)))
    batch = make_batch(cfg)
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # parameters actually moved
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()), params, params2),
    )
    assert delta > 0


@pytest.mark.parametrize("arch", ["qwen3-4b", "zamba2-7b", "mamba2-130m",
                                  "deepseek-moe-16b", "gemma2-27b"])
def test_smoke_decode_step(arch):
    cfg = get_config(arch + "-smoke")
    rt = Runtime(zero_drop=True)
    params = init_params(jax.random.key(0), cfg, jnp.float32)
    batch = make_batch(cfg)
    lg, cache = prefill(params, cfg, batch["tokens"], rt,
                        prefix_embed=batch.get("prefix_embed"), n_slots=40)
    nt = jnp.argmax(lg, -1).astype(jnp.int32)
    lg2, cache, _ = decode_step(params, cfg, nt, cache, rt)
    assert lg2.shape == (2, 1, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(lg2)))
    assert int(cache["pos"]) == batch["tokens"].shape[1] + cfg.prefix_len + 1
