"""MoE dispatch: capacity semantics + equivalence with a dense oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: deterministic local fallback
    from _hypothesis_shim import given, settings, strategies as st

from repro.configs.base import MoESpec
from repro.models.moe import (
    apply_moe_local,
    combine_tokens,
    dispatch_tokens,
    init_moe,
    make_dispatch,
    router_probs,
    top_k_route,
)
from repro.models.runtime import Runtime


def dense_oracle(params, x, spec, gates, eids):
    """y_n = sum_k gate_{nk} E_{e_{nk}}(x_n) with NO capacity drops."""
    from repro.models.common import silu

    outs = []
    for e in range(spec.num_experts):
        h = silu(x @ params["wg"][e]) * (x @ params["wu"][e])
        outs.append(h @ params["wd"][e])
    stack = jnp.stack(outs)  # (E, N, d)
    y = jnp.zeros_like(x)
    for k in range(spec.top_k):
        y = y + gates[:, k : k + 1] * jnp.take_along_axis(
            stack, eids[:, k][None, :, None], axis=0
        )[0]
    return y


def test_local_moe_matches_dense_oracle_zero_drop():
    spec = MoESpec(num_experts=8, top_k=2, d_ff=32)
    d = 16
    params = init_moe(jax.random.key(0), d, spec, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (24, d))
    probs = router_probs(params, x, spec)
    gates, eids = top_k_route(probs, spec.top_k)
    y, _ = apply_moe_local(params, x, spec, Runtime(zero_drop=True), probs=probs)
    ref = dense_oracle(params, x, spec, gates, eids)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4, rtol=1e-3)


def test_shared_expert_added():
    spec = MoESpec(num_experts=4, top_k=1, d_ff=16, num_shared=2, shared_d_ff=32)
    d = 8
    params = init_moe(jax.random.key(0), d, spec, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (6, d))
    y, _ = apply_moe_local(params, x, spec, Runtime(zero_drop=True))
    # zero out shared weights -> output changes
    p2 = dict(params, shared=jax.tree.map(jnp.zeros_like, params["shared"]))
    y2, _ = apply_moe_local(p2, x, spec, Runtime(zero_drop=True))
    assert float(jnp.abs(y - y2).max()) > 1e-5


@given(st.integers(0, 100), st.integers(1, 4), st.integers(1, 8))
@settings(max_examples=20, deadline=None)
def test_dispatch_positions_unique_and_bounded(seed, K, cap):
    E, N = 8, 16
    spec = MoESpec(num_experts=E, top_k=K, d_ff=8)
    probs = jax.nn.softmax(jax.random.normal(jax.random.key(seed), (N, E)), -1)
    gates, eids = top_k_route(probs, K)
    d = make_dispatch(gates, eids, spec, cap)
    kept = np.asarray(d.eids) < E
    pos = np.asarray(d.pos)
    assert (pos[kept] < cap).all()
    # (expert, slot) pairs of kept assignments are unique
    pairs = list(zip(np.asarray(d.eids)[kept], pos[kept]))
    assert len(pairs) == len(set(pairs))
    # dropped assignments have zero gate
    assert (np.asarray(d.gates)[~kept] == 0).all()


def test_capacity_drop_loses_lowest_priority():
    """Tokens are dispatched in order; overflow drops later tokens."""
    spec = MoESpec(num_experts=2, top_k=1, d_ff=4)
    # all 4 tokens pick expert 0
    gates = jnp.ones((4, 1))
    eids = jnp.zeros((4, 1), jnp.int32)
    d = make_dispatch(gates, eids, spec, cap=2)
    kept = np.asarray(d.eids)[:, 0] < 2
    assert kept.tolist() == [True, True, False, False]


def test_dispatch_combine_roundtrip_identity():
    """dispatch + identity expert + combine == gate-scaled input sum."""
    spec = MoESpec(num_experts=4, top_k=2, d_ff=4)
    N, dm = 8, 6
    x = jax.random.normal(jax.random.key(0), (N, dm))
    probs = jax.nn.softmax(jax.random.normal(jax.random.key(1), (N, spec.num_experts)), -1)
    gates, eids = top_k_route(probs, spec.top_k)
    d = make_dispatch(gates, eids, spec, cap=N)
    buf = dispatch_tokens(d, x, spec.num_experts)
    y = combine_tokens(d, buf)
    ref = x * gates.sum(-1, keepdims=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-5, rtol=1e-4)


def test_lora_delta_changes_expert_output():
    spec = MoESpec(num_experts=4, top_k=2, d_ff=16)
    d = 8
    params = init_moe(jax.random.key(0), d, spec, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (10, d))
    rt = Runtime(zero_drop=True)
    y0, _ = apply_moe_local(params, x, spec, rt)
    lora = {
        "wu": {"a": jax.random.normal(jax.random.key(2), (4, d, 2)) * 0.1,
               "b": jax.random.normal(jax.random.key(3), (4, 2, 16)) * 0.1},
        "wd": {"a": jnp.zeros((4, 16, 2)), "b": jnp.zeros((4, 2, d))},
    }
    y1, _ = apply_moe_local(params, x, spec, rt, lora=lora, lora_scale=1.0)
    assert float(jnp.abs(y0 - y1).max()) > 1e-6
    # zero adapters are exactly a no-op
    zl = jax.tree.map(jnp.zeros_like, lora)
    y2, _ = apply_moe_local(params, x, spec, rt, lora=zl, lora_scale=1.0)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y2), atol=1e-6)
