"""Fleet supervision (PR 10): heartbeats, worker fault kinds, graceful
drain, and the supervisor's restart / hang-detection / failover loop.

Fast unit tests (heartbeat files, fault grammar, in-process drain +
resume) run in the core lane; everything that launches real worker
processes is marked ``fleet`` (its own CI lane — each test pays one
fresh jax import + jit warmup per worker process)."""
import json
import os
import signal
import subprocess
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.faults import (
    NULL_FAULT_PLAN,
    FaultPlan,
    parse_fault_spec,
    uninstall_fault_plan,
)
from repro.fleet import (
    HEARTBEAT_NAME,
    FleetConfig,
    FleetSupervisor,
    HeartbeatWriter,
    parse_worker_fault_schedule,
    read_heartbeat,
)
from repro.fleet.supervisor import RESTART_BACKOFF
from repro.models.model import init_params
from repro.recovery import RequestJournal, recover
from repro.serving import ContinuousBatchingServer, RequestQueue, ServeRequest

ARCH = "granite-moe-1b-a400m-smoke"
SRC = str(Path(__file__).resolve().parents[1] / "src")


@pytest.fixture(scope="module")
def setup():
    cfg = get_config(ARCH)
    params = init_params(jax.random.key(0), cfg, jnp.float32)
    return cfg, params


@pytest.fixture(autouse=True)
def _no_leaked_plan():
    uninstall_fault_plan()
    yield
    uninstall_fault_plan()


def mk_requests(cfg, lens, budgets, *, seed=0):
    rng = np.random.default_rng(seed)
    return [
        ServeRequest(rid=i,
                     prompt=rng.integers(0, cfg.vocab, lens[i]).astype(np.int32),
                     max_new_tokens=budgets[i])
        for i in range(len(lens))
    ]


def subproc_env():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("REPRO_FAULTS", None)
    env.pop("REPRO_JOURNAL", None)
    return env


def wait_for(pred, timeout_s, what):
    deadline = time.time() + timeout_s
    while time.time() < deadline:
        if pred():
            return
        time.sleep(0.25)
    raise AssertionError(f"timed out waiting for {what}")


# ---------------------------------------------------------------------------
# heartbeat files
# ---------------------------------------------------------------------------


def test_heartbeat_atomic_throttled_and_pid_stamped(tmp_path):
    hb = HeartbeatWriter(tmp_path / HEARTBEAT_NAME)
    assert hb.beat(phase="init")
    got = read_heartbeat(tmp_path / HEARTBEAT_NAME)
    assert got["seq"] == 1 and got["phase"] == "init"
    assert got["pid"] == os.getpid()  # the incarnation guard
    # throttle: a beat younger than min_interval_s is suppressed...
    assert not hb.beat(phase="serving", step=3, min_interval_s=60.0)
    assert read_heartbeat(tmp_path / HEARTBEAT_NAME)["seq"] == 1
    # ...but a phase-change beat (interval 0) always publishes
    assert hb.beat(phase="drained", step=3, finished=2)
    got = read_heartbeat(tmp_path / HEARTBEAT_NAME)
    assert got["seq"] == 2 and got["step"] == 3 and got["finished"] == 2
    # atomic replace leaves no tmp litter
    assert sorted(p.name for p in tmp_path.iterdir()) == [HEARTBEAT_NAME]
    assert read_heartbeat(tmp_path / "missing.json") is None


# ---------------------------------------------------------------------------
# worker-level fault grammar: kill= / hang=
# ---------------------------------------------------------------------------


def test_kill_hang_fault_grammar_and_determinism():
    cfg = parse_fault_spec("kill_at=3,seed=1")
    assert cfg.kill_at == 3 and cfg.any_active
    plan = FaultPlan(cfg)
    assert not plan.maybe_kill() and not plan.maybe_kill()
    assert plan.maybe_kill("step")  # third call
    assert plan.counters["kill"] == 1

    cfg = parse_fault_spec("hang_at=2:45")
    assert cfg.hang_at == 2 and cfg.hang_s == 45.0
    plan = FaultPlan(cfg)
    assert plan.maybe_hang() == 0.0
    assert plan.maybe_hang() == 45.0
    assert plan.counters["hang"] == 1

    def kill_point(seed):
        p = FaultPlan(parse_fault_spec(f"kill=0.2,seed={seed}"))
        for i in range(1, 200):
            if p.maybe_kill():
                return i
        return None

    assert kill_point(5) is not None
    assert kill_point(5) == kill_point(5)  # seeded rate is deterministic
    # the null plan never fires and costs nothing
    assert not NULL_FAULT_PLAN.maybe_kill()
    assert NULL_FAULT_PLAN.maybe_hang() == 0.0


def test_parse_worker_fault_schedule():
    sched = parse_worker_fault_schedule("0:kill_at=6;2:hang_at=4:30,seed=1")
    assert set(sched) == {0, 2}
    assert parse_fault_spec(sched[0]).kill_at == 6
    assert parse_fault_spec(sched[2]).hang_s == 30.0
    assert parse_worker_fault_schedule(None) == {}
    assert parse_worker_fault_schedule("") == {}
    with pytest.raises(ValueError):
        parse_worker_fault_schedule("0:frobnicate=1")  # typo fails eagerly


def test_restart_backoff_jittered_capped_decorrelated():
    # capped exponential even at huge attempt counts
    assert RESTART_BACKOFF.backoff(50, salt=0) <= RESTART_BACKOFF.backoff_cap_s
    # deterministic per (salt, attempt); distinct salts decorrelate a
    # correlated failure so the fleet doesn't restart in lockstep
    assert (RESTART_BACKOFF.backoff(2, salt=1)
            == RESTART_BACKOFF.backoff(2, salt=1))
    assert len({RESTART_BACKOFF.backoff(2, salt=s) for s in range(8)}) > 1


# ---------------------------------------------------------------------------
# graceful drain: in-process (fast) — stop admission, final anchored
# checkpoint, token-identical resume
# ---------------------------------------------------------------------------


def test_continuous_drain_then_resume_token_identical(setup, tmp_path):
    cfg, params = setup
    lens, budgets = [6, 9, 7, 11], [8, 5, 10, 6]
    ref, _ = ContinuousBatchingServer(
        cfg, params, n_slots=2, max_len=32).run(
            RequestQueue(mk_requests(cfg, lens, budgets)))

    srv = ContinuousBatchingServer(cfg, params, n_slots=2, max_len=32)
    jr = RequestJournal(tmp_path)
    steps = {"n": 0}

    def on_step(info):
        steps["n"] += 1

    results, mt = srv.run(
        RequestQueue(mk_requests(cfg, lens, budgets)), journal=jr,
        checkpoint_every=3, on_step=on_step,
        should_drain=lambda: steps["n"] >= 4)
    jr.close()
    assert srv.drained and steps["n"] >= 4
    assert len(results) < len(lens), "drain should leave work behind"

    state = recover(tmp_path)
    assert state is not None and state.kind == "continuous"
    assert state.pending, "drain checkpoint should carry live requests"
    srv2 = ContinuousBatchingServer(cfg, params, n_slots=2, max_len=32)
    jr2 = RequestJournal(tmp_path, seen=state.seen_rids)
    rest, mt2 = srv2.run(state.build_queue(None), state.metrics,
                         journal=jr2, resume=state)
    jr2.close()
    assert not srv2.drained  # no drain signal on the second leg
    by = {r.rid: r for r in list(results) + list(rest)}
    assert sorted(by) == [0, 1, 2, 3]
    for a in ref:
        np.testing.assert_array_equal(a.tokens, by[a.rid].tokens)
        assert a.finish_reason == by[a.rid].finish_reason
    assert mt2.generated_tokens == sum(len(r.tokens) for r in ref)


# ---------------------------------------------------------------------------
# subprocess tests: real workers, real signals (fleet CI lane)
# ---------------------------------------------------------------------------


@pytest.mark.fleet
def test_bench_serve_sigterm_drains_checkpoints_and_resumes(tmp_path):
    """SIGTERM mid-serve => exit 0, 'DRAINED' banner, journal holds the
    remainder; a --resume run completes token-identically vs an
    uninterrupted reference run."""
    common = [sys.executable, "-m", "repro.launch.bench_serve",
              "--arch", ARCH, "--n-requests", "8", "--slots", "2",
              "--arrival", "all_at_once", "--prompt-len", "10",
              "--max-new", "10", "--seed", "0"]
    env = subproc_env()

    ref_path = tmp_path / "ref.json"
    subprocess.run(common + ["--out-results", str(ref_path)], env=env,
                   check=True, timeout=300, stdout=subprocess.DEVNULL)
    ref = {r["rid"]: r["tokens"]
           for r in json.loads(ref_path.read_text())["results"]}

    jdir = tmp_path / "journal"
    out1 = tmp_path / "drained.json"
    proc = subprocess.Popen(
        common + ["--journal", str(jdir), "--checkpoint-every", "2",
                  "--out-results", str(out1)],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True)
    try:
        # wait for journal evidence that serving is underway, then drain
        wait_for(lambda: '"ev"' in ((jdir / "journal.jsonl").read_text()
                                    if (jdir / "journal.jsonl").exists()
                                    else ""),
                 timeout_s=240, what="journal activity")
        proc.send_signal(signal.SIGTERM)
        stdout, _ = proc.communicate(timeout=240)
    finally:
        proc.kill()
    assert proc.returncode == 0, stdout
    assert "DRAINED on SIGTERM" in stdout

    got = {r["rid"]: r["tokens"]
           for r in json.loads(out1.read_text())["results"]}
    state = recover(jdir)
    assert state is not None
    if state.pending:  # SIGTERM landed mid-serve, not after the fact
        out2 = tmp_path / "resumed.json"
        subprocess.run(common + ["--journal", str(jdir), "--resume",
                                 "--out-results", str(out2)],
                       env=env, check=True, timeout=300,
                       stdout=subprocess.DEVNULL)
        for r in json.loads(out2.read_text())["results"]:
            got[r["rid"]] = r["tokens"]
    assert got == ref


def _fleet_requests(cfg, n=6):
    return mk_requests(cfg, [6, 9, 7, 11, 8, 5][:n], [8, 5, 10, 6, 7, 9][:n])


@pytest.mark.fleet
def test_fleet_kill_restart_token_identical(setup, tmp_path):
    """An injected mid-step kill (os._exit, journal current through the
    last step) is detected as a crash; the restarted incarnation
    recovers from its journal and the fleet finishes everything
    token-identical to a single uninterrupted server."""
    cfg, params = setup
    base = _fleet_requests(cfg)
    ref, _ = ContinuousBatchingServer(cfg, params, n_slots=2, max_len=32).run(
        RequestQueue(_fleet_requests(cfg)))
    ref_tokens = {r.rid: [int(t) for t in r.tokens] for r in ref}

    fcfg = FleetConfig(n_workers=2, arch=ARCH, slots=2, checkpoint_every=2,
                       heartbeat_s=0.2,
                       worker_faults={0: "kill_at=4,seed=0"})
    sup = FleetSupervisor(base, fcfg, tmp_path)
    report = sup.run(max_wall_s=240.0)

    assert report["restarts"]["crash"] >= 1
    assert report["unaccounted"] == [] and not report["pending_checkpointed"]
    assert report["finished"] == len(base)
    got = {int(rid): r["tokens"] for rid, r in report["results"].items()}
    assert got == ref_tokens
    assert len(report["failover_s"]["samples"]) >= 1
    kinds = {e["event"] for e in report["events"]}
    assert "crash" in kinds and "hang_detected" not in kinds
    prom = sup.prometheus_text()
    assert 'worker_restarts_total{reason="crash"} 1' in prom.replace(".0", "")
    assert "fleet_failover_s_bucket" in prom


@pytest.mark.fleet
def test_fleet_hang_detected_distinct_from_crash(setup, tmp_path):
    """A hung worker keeps its process alive (a waitpid loop sees
    nothing) — only heartbeat staleness can catch it. The supervisor
    SIGKILLs, books the restart under reason=hang, and the fleet still
    finishes token-identically."""
    cfg, params = setup
    base = _fleet_requests(cfg)
    ref, _ = ContinuousBatchingServer(cfg, params, n_slots=2, max_len=32).run(
        RequestQueue(_fleet_requests(cfg)))
    ref_tokens = {r.rid: [int(t) for t in r.tokens] for r in ref}

    fcfg = FleetConfig(n_workers=2, arch=ARCH, slots=2, checkpoint_every=2,
                       heartbeat_s=0.2, hang_deadline_s=2.0,
                       worker_faults={0: "hang_at=3:120"})
    sup = FleetSupervisor(base, fcfg, tmp_path)
    report = sup.run(max_wall_s=240.0)

    assert report["restarts"]["hang"] >= 1
    assert report["restarts"]["crash"] == 0  # the distinction under test
    kinds = {e["event"] for e in report["events"]}
    assert "hang_detected" in kinds and "crash" not in kinds
    assert report["unaccounted"] == [] and not report["pending_checkpointed"]
    got = {int(rid): r["tokens"] for rid, r in report["results"].items()}
    assert got == ref_tokens


@pytest.mark.fleet
def test_fleet_supervisor_sigterm_drains_exit_zero(tmp_path):
    """SIGTERM to the fleet launcher: every worker stops admission,
    finishes in-flight, checkpoints and exits 0; the supervisor exits 0
    with every request finished or checkpointed."""
    out = tmp_path / "report.json"
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.bench_fleet",
         "--arch", ARCH, "--workers", "2", "--n-requests", "10",
         "--prompt-len", "8", "--max-new", "10",
         "--dir", str(tmp_path / "fleet"), "--out", str(out)],
        env=subproc_env(), stdout=subprocess.PIPE,
        stderr=subprocess.STDOUT, text=True)
    try:
        def journaled():
            return any(p.stat().st_size > 0 for p in
                       tmp_path.glob("fleet/worker-*/journal/journal.jsonl"))
        wait_for(journaled, timeout_s=240, what="worker journal activity")
        proc.send_signal(signal.SIGTERM)
        stdout, _ = proc.communicate(timeout=240)
    finally:
        proc.kill()
    assert proc.returncode == 0, stdout

    report = json.loads(out.read_text())
    assert report["drained"]
    assert report["unaccounted"] == []
    assert (report["finished"] + len(report["pending_checkpointed"])
            == report["n_requests"])
    for w in report["workers"]:
        assert w["exit_code"] == 0, (w, stdout)
