"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.kernels

from repro.kernels.int4_matmul import int4_matmul, quantize_matmul_weight
from repro.kernels.int4_matmul.ref import dequant_ref, int4_matmul_ref
from repro.kernels.moe_gmm import gmm, gmm_ref
from repro.kernels.ssd_scan import ssd, ssd_scan_ref


# ---------------------------------------------------------------------------
# int4 dequant matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "M,K,N,group,bm,bn,bk",
    [
        (64, 128, 96, 32, 32, 32, 64),
        (256, 512, 256, 64, 128, 128, 512),
        (8, 256, 128, 64, 8, 128, 128),
        (128, 1024, 64, 128, 64, 64, 256),
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_int4_matmul_vs_ref(M, K, N, group, bm, bn, bk, dtype):
    x = jax.random.normal(jax.random.key(1), (M, K)).astype(dtype)
    w = jax.random.normal(jax.random.key(2), (K, N)) * 0.05
    qw = quantize_matmul_weight(w, group)
    ref = int4_matmul_ref(x, qw.packed, qw.scale, qw.zero, group)
    out = int4_matmul(x, qw.packed, qw.scale, qw.zero, group=group,
                      bm=bm, bn=bn, bk=bk, interpret=True)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


def test_int4_pack_roundtrip_and_quality():
    K, N, group = 256, 64, 64
    w = jax.random.normal(jax.random.key(0), (K, N)) * 0.1
    qw = quantize_matmul_weight(w, group)
    assert qw.packed.shape == (K // 2, N) and qw.packed.dtype == jnp.uint8
    wd = dequant_ref(qw.packed, qw.scale, qw.zero, group)
    err = float(jnp.abs(wd - w).mean())
    rng = float(w.max() - w.min())
    assert err < rng / 15  # better than one quantization bin on average


# ---------------------------------------------------------------------------
# grouped expert matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "E,M,K,N", [(4, 64, 128, 96), (8, 33, 256, 128), (2, 7, 64, 32), (1, 128, 512, 64)]
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gmm_vs_ref(E, M, K, N, dtype):
    a = jax.random.normal(jax.random.key(0), (E, M, K)).astype(dtype)
    b = jax.random.normal(jax.random.key(1), (E, K, N)).astype(dtype)
    out = gmm(a, b, interpret=True)
    ref = gmm_ref(a, b)
    tol = 1e-4 if dtype == jnp.float32 else 1e-1
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize(
    "E,M,K,N,sizes",
    [
        (4, 64, 128, 96, (0, 17, 64, 3)),
        (8, 33, 256, 128, (33, 0, 0, 5, 12, 33, 1, 0)),
        (2, 7, 64, 32, (0, 0)),
    ],
)
def test_gmm_ragged_group_sizes(E, M, K, N, sizes):
    """Ragged groups: rows >= sizes[e] are zero in a (the slot-dispatch
    contract); the kernel skips those tiles and must still match the
    dense reference on the full output."""
    a = jax.random.normal(jax.random.key(0), (E, M, K), jnp.float32)
    mask = (np.arange(M)[None, :] < np.asarray(sizes)[:, None])[..., None]
    a = a * mask
    b = jax.random.normal(jax.random.key(1), (E, K, N), jnp.float32)
    out = gmm(a, b, interpret=True, group_sizes=jnp.asarray(sizes, jnp.int32))
    ref = gmm_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4,
                               rtol=1e-4)
    # the zeroed tail of every group stays exactly zero in the output
    for e, s in enumerate(sizes):
        assert not np.asarray(out)[e, s:].any()


# ---------------------------------------------------------------------------
# SSD chunked scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "B,T,H,P,N,chunk",
    [(2, 64, 3, 16, 8, 16), (1, 128, 2, 32, 16, 32), (3, 96, 1, 8, 4, 32)],
)
def test_ssd_vs_sequential_ref(B, T, H, P, N, chunk):
    x = jax.random.normal(jax.random.key(2), (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.key(3), (B, T, H)))
    A = -jnp.exp(jax.random.normal(jax.random.key(4), (H,)) * 0.3)
    Bm = jax.random.normal(jax.random.key(5), (B, T, N)) * 0.5
    Cm = jax.random.normal(jax.random.key(6), (B, T, N)) * 0.5
    y, fin = ssd(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    yr, fr = ssd_scan_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=5e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(fr), atol=5e-4, rtol=1e-3)


def test_ssd_state_isolation_across_batch_heads():
    """The VMEM-carried state must reset between (batch, head) programs."""
    B, T, H, P, N = 2, 32, 2, 8, 4
    x = jnp.zeros((B, T, H, P)).at[0].set(
        jax.random.normal(jax.random.key(7), (T, H, P)) * 3
    )
    dt = jax.nn.softplus(jnp.ones((B, T, H)))
    A = -jnp.ones((H,))
    Bm = jnp.ones((B, T, N)) * 0.3
    Cm = jnp.ones((B, T, N)) * 0.3
    y, fin = ssd(x, dt, A, Bm, Cm, chunk=8, interpret=True)
    # batch 1 has zero input -> zero output and zero final state
    assert float(jnp.abs(y[1]).max()) == 0.0
    assert float(jnp.abs(fin[1]).max()) == 0.0


# ---------------------------------------------------------------------------
# Flash attention (forward)
# ---------------------------------------------------------------------------

from repro.kernels.flash_attn import attention_ref, flash


@pytest.mark.parametrize(
    "B,T,Hkv,G,hd,cap,win,bq,bk",
    [
        (2, 64, 2, 2, 16, None, None, 16, 16),
        (1, 128, 1, 4, 32, 50.0, None, 32, 32),
        (2, 96, 2, 1, 16, None, 32, 32, 16),
        (1, 64, 2, 2, 16, 30.0, 24, 16, 16),
    ],
)
def test_flash_attn_vs_ref(B, T, Hkv, G, hd, cap, win, bq, bk):
    q = jax.random.normal(jax.random.key(0), (B, T, Hkv, G, hd))
    k = jax.random.normal(jax.random.key(1), (B, T, Hkv, hd))
    v = jax.random.normal(jax.random.key(2), (B, T, Hkv, hd))
    out = flash(q, k, v, softcap=cap, window=win, bq=bq, bk=bk, interpret=True)
    ref = attention_ref(q, k, v, softcap=cap, window=win)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=1e-4)
