"""Property tests for the rank-matching loss (paper App C.2)."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: deterministic local fallback
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.rank_match import inversion_count, rank_match_loss, rank_match_token


def probs(seed, *shape):
    return jax.nn.softmax(jax.random.normal(jax.random.key(seed), shape), -1)


@given(st.integers(0, 200), st.integers(3, 16), st.floats(0.01, 0.3))
@settings(max_examples=40, deadline=None)
def test_lemma_c8_lower_bound(seed, E, rho):
    """Lemma C.8: m >= rho * Inv(pf, pb)."""
    pb = probs(seed, E)
    pf = probs(seed + 1, E)
    m = float(rank_match_token(pb, pf, rho))
    inv = float(inversion_count(pb, pf))
    assert m >= rho * inv - 1e-6


def test_zero_inversions_when_orders_match_with_margin():
    E, rho = 6, 0.05
    pb = jnp.asarray([0.4, 0.25, 0.15, 0.1, 0.06, 0.04])
    assert float(inversion_count(pb, pb)) == 0
    # margins of pb are all >= 0.02; with rho below min margin, loss is 0
    m = float(rank_match_token(pb, pb, 0.01))
    assert m == 0.0
    # reversed order: every base-ordered pair is inverted
    m_rev = float(rank_match_token(pb, pb[::-1], rho))
    assert m_rev > 0
    assert float(inversion_count(pb, pb[::-1])) == 15  # C(6,2)


def test_batched_loss_matches_tokenwise_mean():
    B, T, E, rho = 2, 13, 8, 0.1
    pb = probs(10, B, T, E)
    pf = probs(11, B, T, E)
    loss = float(rank_match_loss(pb, pf, rho=rho, token_chunk=5))
    ref = float(rank_match_token(pb, pf, rho).mean())
    np.testing.assert_allclose(loss, ref, rtol=1e-5)


def test_gradient_pushes_toward_base_order():
    """Gradient should increase pf_i - pf_j for base-preferred pairs."""
    E, rho = 4, 0.1
    pb = jnp.asarray([0.7, 0.2, 0.07, 0.03])
    logits = jnp.zeros((1, 1, E))

    def f(lg):
        pf = jax.nn.softmax(lg, -1)
        return rank_match_loss(jnp.broadcast_to(pb, (1, 1, E)), pf, rho=rho)

    g = jax.grad(f)(logits)[0, 0]
    # descending the loss raises the top base expert relative to the last
    assert float(g[0]) < float(g[-1])
