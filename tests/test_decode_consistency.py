"""Prefill + step-by-step decode must match the full forward pass —
the strongest end-to-end correctness check across every block family."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import Runtime, apply_model, decode_step, init_params, prefill

FAMILIES = [
    "qwen3-4b",  # dense + qk_norm
    "gemma2-27b",  # local/global alternation + softcaps + tied embeddings
    "granite-moe-1b-a400m",  # MoE
    "deepseek-moe-16b",  # MoE + shared experts + dense layer 0
    "mamba2-130m",  # pure SSM
    "zamba2-7b",  # hybrid mamba + shared attention
    "musicgen-medium",  # audio prefix
    "internvl2-76b",  # vlm prefix
]


@pytest.mark.parametrize("arch", FAMILIES)
def test_decode_matches_full_forward(arch):
    cfg = get_config(arch + "-smoke")
    rt = Runtime(zero_drop=True)
    params = init_params(jax.random.key(0), cfg, jnp.float32)
    B, T, G = 2, 24, 6
    toks = jax.random.randint(jax.random.key(1), (B, T + G), 0, cfg.vocab)
    pe = (
        jax.random.normal(jax.random.key(2), (B, cfg.prefix_len, cfg.d_model))
        if cfg.prefix_len
        else None
    )
    logits_full, _ = apply_model(params, cfg, toks, rt, prefix_embed=pe)
    lg, cache = prefill(params, cfg, toks[:, :T], rt, prefix_embed=pe,
                        n_slots=cfg.prefix_len + T + G)
    outs = [lg]
    for i in range(G):
        lg, cache, _ = decode_step(params, cfg, toks[:, T + i : T + i + 1], cache, rt)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    ref = logits_full[:, cfg.prefix_len + T - 1 :]
    err = float(jnp.max(jnp.abs(dec - ref)))
    assert err < 5e-3, f"{arch}: {err}"
